//! Analytic MAC accounting (paper's TMACs columns + Fig. 5).
//!
//! Counts multiply-accumulates of every matmul in the exported HLO
//! programs from the family geometry, so the TMACs columns of Tables
//! 1–3 and the compute-composition pie of Fig. 5 are reproduced without
//! instrumentation. A caching schedule scales each branch type's count
//! by its compute fraction.

use crate::cache::Schedule;
use crate::model::FamilyManifest;

/// MACs of one branch evaluation for a single sample (batch 1).
pub fn branch_macs(fm: &FamilyManifest, branch: &str) -> u64 {
    let d = fm.hidden as u64;
    let s = fm.seq_len as u64;
    let sc = fm.cond_len as u64;
    let f = (fm.hidden * fm.mlp_ratio) as u64;
    let modulation = d * 3 * d; // silu(c) @ mod_w
    if branch.ends_with("xattn") {
        // q proj + kv proj + scores + attn·V + out proj
        modulation + s * d * d + sc * d * 2 * d + 2 * s * sc * d + s * d * d
    } else if branch.ends_with("attn") {
        // attention span: full sequence for plain attn; within-frame for
        // spatial (s_*), across-frame for temporal (t_*)
        let span = if branch.starts_with("s_") {
            fm.spatial_tokens as u64
        } else if branch.starts_with("t_") {
            fm.frames as u64
        } else {
            s
        };
        modulation + s * d * 3 * d + 2 * s * span * d + s * d * d
    } else {
        // ffn: two GEMMs through the hidden width
        modulation + 2 * s * d * f
    }
}

/// MACs of the embed entry (patchify + timestep MLP), batch 1.
pub fn embed_macs(fm: &FamilyManifest) -> u64 {
    let d = fm.hidden as u64;
    let s = fm.seq_len as u64;
    let pd: u64 = (fm.latent_size() / fm.seq_len) as u64; // patch dim
    s * pd * d + (fm.t_freq_dim as u64) * d + d * d
}

/// MACs of the final head, batch 1.
pub fn final_macs(fm: &FamilyManifest) -> u64 {
    let d = fm.hidden as u64;
    let s = fm.seq_len as u64;
    let pd: u64 = (fm.latent_size() / fm.seq_len) as u64;
    d * 2 * d + s * d * pd
}

/// MACs of one full forward pass (all branches computed), batch 1.
pub fn forward_macs(fm: &FamilyManifest) -> u64 {
    let branches: u64 = fm
        .branch_types
        .iter()
        .map(|b| branch_macs(fm, b) * fm.depth as u64)
        .sum();
    embed_macs(fm) + branches + final_macs(fm)
}

/// Fraction of forward MACs that live in cacheable branches (Fig. 5's
/// ">90% of compute" observation).
pub fn cacheable_fraction(fm: &FamilyManifest) -> f64 {
    let total = forward_macs(fm) as f64;
    let cacheable =
        total - embed_macs(fm) as f64 - final_macs(fm) as f64;
    cacheable / total
}

/// Per-branch-type share of one forward pass (Fig. 5 composition).
pub fn composition(fm: &FamilyManifest) -> Vec<(String, f64)> {
    let total = forward_macs(fm) as f64;
    let mut out: Vec<(String, f64)> = fm
        .branch_types
        .iter()
        .map(|b| {
            (b.clone(), (branch_macs(fm, b) * fm.depth as u64) as f64 / total)
        })
        .collect();
    out.push(("embed+final".into(), (embed_macs(fm) + final_macs(fm)) as f64 / total));
    out
}

/// Total MACs for a full generation under a schedule, per sample.
/// `cfg` doubles every model evaluation (conditional + null batch).
pub fn generation_macs(fm: &FamilyManifest, schedule: &Schedule, cfg: bool) -> u64 {
    let per_step_fixed = embed_macs(fm) + final_macs(fm);
    let mut total = per_step_fixed * schedule.steps as u64;
    for (bt, computes) in schedule.branch_types.iter().zip(schedule.computes_per_type()) {
        total += branch_macs(fm, bt) * fm.depth as u64 * computes as u64;
    }
    if cfg {
        total *= 2;
    }
    total
}

/// Human-scale units used in the paper's tables.
pub fn as_gmacs(macs: u64) -> f64 {
    macs as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn image_fm() -> FamilyManifest {
        // minimal manifest mirroring the image family geometry
        let text = r#"{
          "version": 1, "impl": "pallas", "batch_sizes": [1],
          "families": {"image": {
            "hidden": 128, "heads": 4, "depth": 6, "mlp_ratio": 4,
            "seq_len": 64, "latent_shape": [16, 16, 4],
            "branch_types": ["attn", "ffn"],
            "cond_len": 0, "num_classes": 10, "vocab": 0,
            "frames": 0, "spatial_tokens": 0, "patch": 2, "t_freq_dim": 64,
            "weights_file": "w.bin", "impl": "pallas", "entries": {}
          }}}"#;
        Manifest::parse_str(text).unwrap().family("image").unwrap().clone()
    }

    fn video_fm() -> FamilyManifest {
        let text = r#"{
          "version": 1, "impl": "pallas", "batch_sizes": [1],
          "families": {"video": {
            "hidden": 128, "heads": 4, "depth": 4, "mlp_ratio": 4,
            "seq_len": 64, "latent_shape": [4, 8, 8, 4],
            "branch_types": ["s_attn", "s_xattn", "s_ffn", "t_attn", "t_xattn", "t_ffn"],
            "cond_len": 8, "num_classes": 0, "vocab": 256,
            "frames": 4, "spatial_tokens": 16, "patch": 2, "t_freq_dim": 64,
            "weights_file": "w.bin", "impl": "pallas", "entries": {}
          }}}"#;
        Manifest::parse_str(text).unwrap().family("video").unwrap().clone()
    }

    #[test]
    fn attn_macs_formula() {
        let fm = image_fm();
        let d = 128u64;
        let s = 64u64;
        let want = d * 3 * d + s * d * 3 * d + 2 * s * s * d + s * d * d;
        assert_eq!(branch_macs(&fm, "attn"), want);
    }

    #[test]
    fn ffn_macs_formula() {
        let fm = image_fm();
        let want = 128 * 3 * 128 + 2 * 64 * 128 * 512;
        assert_eq!(branch_macs(&fm, "ffn"), want);
    }

    #[test]
    fn spatial_attention_cheaper_than_full() {
        let fm = video_fm();
        assert!(branch_macs(&fm, "s_attn") < {
            // full-span attention at the same geometry
            let d = 128u64;
            let s = 64u64;
            d * 3 * d + s * d * 3 * d + 2 * s * s * d + s * d * d
        });
        assert!(branch_macs(&fm, "t_attn") < branch_macs(&fm, "s_attn"));
    }

    #[test]
    fn cacheable_fraction_dominates() {
        // paper Fig. 5: cacheable layers are ≥ 90% of compute
        assert!(cacheable_fraction(&image_fm()) > 0.9);
        assert!(cacheable_fraction(&video_fm()) > 0.9);
    }

    #[test]
    fn composition_sums_to_one() {
        for fm in [image_fm(), video_fm()] {
            let total: f64 = composition(&fm).iter().map(|(_, f)| f).sum();
            assert!((total - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn schedule_scales_generation_macs() {
        let fm = image_fm();
        let bts = fm.branch_types.clone();
        let full = generation_macs(&fm, &Schedule::no_cache(50, &bts), false);
        let half = generation_macs(&fm, &Schedule::fora(50, &bts, 2), false);
        assert!(half < full);
        // fora n=2 halves branch MACs but not embed/final
        let branch_full = full - 50 * (embed_macs(&fm) + final_macs(&fm));
        let branch_half = half - 50 * (embed_macs(&fm) + final_macs(&fm));
        assert_eq!(branch_half, branch_full / 2);
    }

    #[test]
    fn cfg_doubles() {
        let fm = image_fm();
        let bts = fm.branch_types.clone();
        let s = Schedule::no_cache(10, &bts);
        assert_eq!(generation_macs(&fm, &s, true), 2 * generation_macs(&fm, &s, false));
    }
}
