//! Noise schedules: continuous-time wrappers over the discrete training
//! schedule, plus the rectified-flow linear path.
//!
//! The image family is trained (python/compile/train.py) with the
//! standard linear-beta DDPM schedule, T=1000; `alpha_bar(t)` here
//! reproduces that discretisation exactly so the Rust solvers see the
//! same forward process the model was trained under.

pub const T_TRAIN: usize = 1000;

/// Common interface over diffusion noise schedules: everything the
/// solvers need derives from ᾱ(t).
pub trait AlphaBar {
    /// Cumulative ᾱ(t) for continuous t ∈ [0, 1].
    fn alpha_bar(&self, t: f64) -> f64;

    /// alpha(t) = sqrt(ᾱ), the signal coefficient.
    fn alpha(&self, t: f64) -> f64 {
        self.alpha_bar(t).sqrt()
    }

    /// sigma(t) = sqrt(1 − ᾱ), the noise coefficient.
    fn sigma(&self, t: f64) -> f64 {
        (1.0 - self.alpha_bar(t)).max(1e-12).sqrt()
    }

    /// Half-log-SNR λ(t) = ln(alpha/sigma), used by DPM-Solver++.
    fn lambda(&self, t: f64) -> f64 {
        (self.alpha(t) / self.sigma(t)).ln()
    }
}

/// Linear-beta schedule (beta: 1e-4 → 0.02 over 1000 steps).
#[derive(Clone, Debug)]
pub struct LinearBeta {
    log_ab: Vec<f64>,
}

impl Default for LinearBeta {
    fn default() -> Self {
        Self::new()
    }
}

impl LinearBeta {
    pub fn new() -> LinearBeta {
        let mut log_ab = Vec::with_capacity(T_TRAIN);
        let mut acc = 0.0f64;
        for i in 0..T_TRAIN {
            let beta = 1e-4 + (0.02 - 1e-4) * i as f64 / (T_TRAIN - 1) as f64;
            acc += (1.0 - beta).ln();
            log_ab.push(acc);
        }
        LinearBeta { log_ab }
    }

    /// Cumulative ᾱ(t) for continuous t ∈ [0, 1] (matches train.py).
    pub fn alpha_bar(&self, t: f64) -> f64 {
        if t <= 0.0 {
            return 1.0;
        }
        let idx = ((t * (T_TRAIN - 1) as f64) as usize).min(T_TRAIN - 1);
        self.log_ab[idx].exp()
    }

    /// alpha(t) = sqrt(ᾱ), the signal coefficient.
    pub fn alpha(&self, t: f64) -> f64 {
        self.alpha_bar(t).sqrt()
    }

    /// sigma(t) = sqrt(1 - ᾱ), the noise coefficient.
    pub fn sigma(&self, t: f64) -> f64 {
        (1.0 - self.alpha_bar(t)).max(1e-12).sqrt()
    }

    /// Half-log-SNR λ(t) = ln(alpha/sigma), used by DPM-Solver++.
    pub fn lambda(&self, t: f64) -> f64 {
        (self.alpha(t) / self.sigma(t)).ln()
    }
}

impl AlphaBar for LinearBeta {
    fn alpha_bar(&self, t: f64) -> f64 {
        LinearBeta::alpha_bar(self, t)
    }
}

/// Nichol & Dhariwal cosine schedule:
/// ᾱ(t) = cos²(((t + s)/(1 + s))·π/2) / cos²((s/(1 + s))·π/2), s = 0.008.
///
/// Extension feature: the image family is *trained* under the linear
/// schedule, so cosine is for solver-compatibility experiments, not the
/// default sampling path.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cosine;

impl AlphaBar for Cosine {
    fn alpha_bar(&self, t: f64) -> f64 {
        const S: f64 = 0.008;
        let f = |u: f64| ((u + S) / (1.0 + S) * std::f64::consts::FRAC_PI_2).cos().powi(2);
        (f(t.clamp(0.0, 1.0)) / f(0.0)).clamp(1e-9, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_bar_monotone_decreasing() {
        let s = LinearBeta::new();
        let mut prev = s.alpha_bar(0.0);
        assert!((prev - 1.0).abs() < 1e-12);
        for i in 1..=100 {
            let t = i as f64 / 100.0;
            let ab = s.alpha_bar(t);
            assert!(ab < prev, "t={t}");
            assert!(ab > 0.0);
            prev = ab;
        }
    }

    #[test]
    fn signal_noise_unit_norm() {
        let s = LinearBeta::new();
        for i in 0..=10 {
            let t = i as f64 / 10.0;
            let total = s.alpha(t).powi(2) + s.sigma(t).powi(2);
            // sigma uses max(1-ab, eps), so near t=0 the identity is approximate
            assert!((total - 1.0).abs() < 1e-6, "t={t} total={total}");
        }
    }

    #[test]
    fn lambda_monotone_decreasing_in_t() {
        let s = LinearBeta::new();
        let mut prev = s.lambda(0.01);
        for i in 2..=100 {
            let t = i as f64 / 100.0;
            let l = s.lambda(t);
            assert!(l < prev, "t={t}");
            prev = l;
        }
    }

    #[test]
    fn terminal_snr_is_low() {
        let s = LinearBeta::new();
        // at t=1 the process should be nearly pure noise
        assert!(s.alpha_bar(1.0) < 0.01);
    }

    #[test]
    fn cosine_schedule_monotone_and_bounded() {
        let c = Cosine;
        let mut prev = AlphaBar::alpha_bar(&c, 0.0);
        assert!((prev - 1.0).abs() < 1e-9);
        for i in 1..=50 {
            let t = i as f64 / 50.0;
            let ab = AlphaBar::alpha_bar(&c, t);
            assert!(ab <= prev + 1e-12 && ab > 0.0, "t={t}");
            prev = ab;
        }
        assert!(AlphaBar::alpha_bar(&c, 1.0) < 1e-3);
    }

    #[test]
    fn cosine_decays_slower_early_than_linear() {
        // the cosine schedule's signature property: more signal retained
        // at small t than linear-beta
        let lin = LinearBeta::new();
        let cos = Cosine;
        assert!(AlphaBar::alpha_bar(&cos, 0.25) > lin.alpha_bar(0.25));
    }
}
