//! Second-order Heun integrator for rectified flow (extension feature:
//! OpenSora-style samplers often pair RF with higher-order ODE
//! integrators; SmoothCache must compose with them — §4's "compatible
//! with various common solvers" claim).
//!
//! Unlike the single-evaluation solvers in [`super::SolverRun`], Heun
//! needs TWO model evaluations per step (predictor at t, corrector at
//! t'), so it exposes its own step API; the pipeline drives it through
//! [`HeunRun::stages`].

use crate::tensor::Tensor;

pub struct HeunRun {
    pub ts: Vec<f64>,
}

impl HeunRun {
    pub fn new(steps: usize) -> HeunRun {
        assert!(steps >= 1);
        HeunRun { ts: (0..=steps).map(|i| 1.0 - i as f64 / steps as f64).collect() }
    }

    pub fn steps(&self) -> usize {
        self.ts.len() - 1
    }

    /// For step i: the (t_eval, is_corrector) stages. The final step
    /// falls back to plain Euler (no corrector past t=0).
    pub fn stages(&self, i: usize) -> Vec<(f64, bool)> {
        let t_next = self.ts[i + 1];
        if t_next <= 0.0 {
            vec![(self.ts[i], false)]
        } else {
            vec![(self.ts[i], false), (t_next, true)]
        }
    }

    /// Predictor: Euler step x' = x − dt·v(x, t).
    pub fn predict(&self, i: usize, x: &Tensor, v: &Tensor) -> Tensor {
        let dt = (self.ts[i] - self.ts[i + 1]) as f32;
        x.zip(v, |xv, vv| xv - dt * vv)
    }

    /// Corrector: x' = x − dt/2·(v(x,t) + v(x_pred, t')).
    pub fn correct(&self, i: usize, x: &Tensor, v0: &Tensor, v1: &Tensor) -> Tensor {
        let dt = (self.ts[i] - self.ts[i + 1]) as f32;
        let mut out = x.clone();
        for ((o, &a), &b) in out.data.iter_mut().zip(&v0.data).zip(&v1.data) {
            *o -= dt * 0.5 * (a + b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// On the exact linear velocity field of Gaussian data, Heun at N
    /// steps should match Euler at ~2N steps.
    #[test]
    fn heun_beats_euler_at_equal_evals() {
        // v(x, t) for x0 ~ N(mu, s2), path x_t = (1-t)x0 + t·e
        let (mu, s2) = (1.5f64, 0.25f64);
        let v = |x: &Tensor, t: f64| -> Tensor {
            let c = 1.0 - t;
            let var = c * c * s2 + t * t;
            x.map(|xv| {
                let z = xv as f64 - c * mu;
                let e = t / var * z;
                let x0 = mu + c * s2 / var * z;
                (e - x0) as f32
            })
        };
        let run_euler = |steps: usize, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let mut acc = 0.0;
            let n = 200;
            for _ in 0..n {
                let mut x = Tensor::randn(vec![4], &mut rng);
                let ts: Vec<f64> = (0..=steps).map(|i| 1.0 - i as f64 / steps as f64).collect();
                for i in 0..steps {
                    let vv = v(&x, ts[i]);
                    let dt = (ts[i] - ts[i + 1]) as f32;
                    x = x.zip(&vv, |a, b| a - dt * b);
                }
                acc += x.mean();
            }
            acc / n as f64
        };
        let run_heun = |steps: usize, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let run = HeunRun::new(steps);
            let mut acc = 0.0;
            let n = 200;
            for _ in 0..n {
                let mut x = Tensor::randn(vec![4], &mut rng);
                for i in 0..run.steps() {
                    let stages = run.stages(i);
                    let v0 = v(&x, stages[0].0);
                    if stages.len() == 1 {
                        x = run.predict(i, &x, &v0);
                    } else {
                        let xp = run.predict(i, &x, &v0);
                        let v1 = v(&xp, stages[1].0);
                        x = run.correct(i, &x, &v0, &v1);
                    }
                }
                acc += x.mean();
            }
            acc / n as f64
        };
        // ground truth mean is mu
        let e_err = (run_euler(6, 9) - mu).abs();
        let h_err = (run_heun(3, 9) - mu).abs(); // same model-eval budget
        assert!(
            h_err <= e_err + 0.02,
            "heun {h_err} should be competitive with euler {e_err}"
        );
        // and at equal step counts heun is strictly better
        let e6 = (run_euler(6, 11) - mu).abs();
        let h6 = (run_heun(6, 11) - mu).abs();
        assert!(h6 <= e6 + 1e-3, "heun {h6} vs euler {e6}");
    }

    #[test]
    fn stages_shape() {
        let run = HeunRun::new(4);
        assert_eq!(run.stages(0).len(), 2);
        assert_eq!(run.stages(3).len(), 1); // final Euler step
    }
}
