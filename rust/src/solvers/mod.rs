//! Diffusion samplers: DDIM, ancestral DDPM, DPM-Solver++ (2M / 3M, with
//! an optional SDE noise term), and Rectified-Flow Euler — the solver
//! matrix the paper evaluates SmoothCache under (DDIM for DiT-XL,
//! DPM-Solver++(3M) SDE for Stable Audio Open, RF for OpenSora).
//!
//! Solvers are model-agnostic: the pipeline feeds them the (CFG-merged)
//! model prediction each step; multistep state lives inside
//! [`SolverRun`]. Validated against an analytic Gaussian diffusion in
//! the tests below (exact-eps model ⇒ known terminal distribution).

pub mod heun;
pub mod noise;

pub use heun::HeunRun;
pub use noise::{AlphaBar, Cosine};

use crate::tensor::Tensor;
use crate::util::rng::Rng;
use noise::LinearBeta;

/// What the network's output means to the solver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Prediction {
    /// epsilon (noise) prediction — DDPM-family solvers.
    Epsilon,
    /// velocity v = eps - x0 on the linear path — rectified flow.
    Velocity,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    Ddim,
    DdpmAncestral,
    DpmPP2M,
    /// 3rd-order multistep; `sde` adds the stochastic churn term.
    DpmPP3M { sde: bool },
    RectifiedFlow,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        Some(match s {
            "ddim" => SolverKind::Ddim,
            "ddpm" => SolverKind::DdpmAncestral,
            "dpmpp2m" => SolverKind::DpmPP2M,
            "dpmpp3m" => SolverKind::DpmPP3M { sde: false },
            "dpmpp3m-sde" => SolverKind::DpmPP3M { sde: true },
            "rf" => SolverKind::RectifiedFlow,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Ddim => "ddim",
            SolverKind::DdpmAncestral => "ddpm",
            SolverKind::DpmPP2M => "dpmpp2m",
            SolverKind::DpmPP3M { sde: false } => "dpmpp3m",
            SolverKind::DpmPP3M { sde: true } => "dpmpp3m-sde",
            SolverKind::RectifiedFlow => "rf",
        }
    }

    pub fn prediction(&self) -> Prediction {
        match self {
            SolverKind::RectifiedFlow => Prediction::Velocity,
            _ => Prediction::Epsilon,
        }
    }
}

/// One sampling trajectory: holds the timestep grid and multistep state.
///
/// `Clone` captures the full multistep solver state (x0-prediction
/// history and aligned lambdas), which is what lets a
/// [`crate::pipeline::SessionState`] snapshot resume a parked
/// generation bitwise-identically — including for DPM++ 2M/3M whose
/// step depends on previous predictions.
#[derive(Clone)]
pub struct SolverRun {
    pub kind: SolverKind,
    /// t_0 > t_1 > … > t_{steps} = 0 (length steps+1; step i integrates
    /// t_i → t_{i+1}).
    pub ts: Vec<f64>,
    sched: LinearBeta,
    /// previous x0 predictions (most recent first) for multistep solvers.
    history: Vec<Tensor>,
    /// previous lambda values aligned with history fills.
    lambda_history: Vec<f64>,
}

/// Terminal t for epsilon solvers (avoid the degenerate sigma→0 region
/// of the discrete schedule; standard practice).
const T_MIN: f64 = 1e-3;

impl SolverRun {
    pub fn new(kind: SolverKind, steps: usize) -> SolverRun {
        assert!(steps >= 1);
        let ts = match kind {
            SolverKind::RectifiedFlow => {
                // uniform 1 → 0 Euler grid
                (0..=steps).map(|i| 1.0 - i as f64 / steps as f64).collect()
            }
            _ => {
                // uniform 1 → T_MIN, then a final hop to 0
                let mut ts: Vec<f64> = (0..steps)
                    .map(|i| 1.0 - (1.0 - T_MIN) * i as f64 / steps as f64)
                    .collect();
                ts.push(0.0);
                ts
            }
        };
        SolverRun {
            kind,
            ts,
            sched: LinearBeta::new(),
            history: Vec::new(),
            lambda_history: Vec::new(),
        }
    }

    pub fn steps(&self) -> usize {
        self.ts.len() - 1
    }

    /// The t the model is evaluated at for step i.
    pub fn model_t(&self, i: usize) -> f64 {
        self.ts[i]
    }

    /// Initial latent: standard normal.
    pub fn init_latent(shape: Vec<usize>, rng: &mut Rng) -> Tensor {
        Tensor::randn(shape, rng)
    }

    /// Advance x from t_i to t_{i+1} given the model output at t_i.
    pub fn step(&mut self, i: usize, x: &Tensor, model_out: &Tensor, rng: &mut Rng) -> Tensor {
        let (t, t_next) = (self.ts[i], self.ts[i + 1]);
        match self.kind {
            SolverKind::RectifiedFlow => {
                // x' = x - dt * v  (v points data → noise as t: 0 → 1)
                let dt = t - t_next;
                x.zip(model_out, |xv, v| xv - (dt as f32) * v)
            }
            SolverKind::Ddim => {
                let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
                let (an, sn) = (self.sched.alpha(t_next), self.sched.sigma(t_next));
                // x0 = (x - s·eps)/a ; x' = an·x0 + sn·eps
                x.zip(model_out, |xv, e| {
                    let x0 = (xv - (s as f32) * e) / (a as f32);
                    (an as f32) * x0 + (sn as f32) * e
                })
            }
            SolverKind::DdpmAncestral => {
                let ab = self.sched.alpha_bar(t);
                let abn = self.sched.alpha_bar(t_next);
                let a_step = (ab / abn).clamp(1e-12, 1.0); // per-step alpha
                let beta = 1.0 - a_step;
                let coef = beta / (1.0 - ab).max(1e-12).sqrt();
                let inv_sqrt_a = 1.0 / a_step.sqrt();
                let var = (beta * (1.0 - abn) / (1.0 - ab).max(1e-12)).max(0.0);
                let sd = if t_next > 0.0 { var.sqrt() } else { 0.0 };
                let mut out =
                    x.zip(model_out, |xv, e| (inv_sqrt_a as f32) * (xv - (coef as f32) * e));
                if sd > 0.0 {
                    for v in &mut out.data {
                        *v += (sd as f32) * rng.normal_f32();
                    }
                }
                out
            }
            SolverKind::DpmPP2M | SolverKind::DpmPP3M { .. } => {
                self.dpmpp_step(i, x, model_out, rng)
            }
        }
    }

    /// DPM-Solver++ multistep update (data-prediction formulation).
    fn dpmpp_step(&mut self, i: usize, x: &Tensor, eps: &Tensor, rng: &mut Rng) -> Tensor {
        let (t, t_next) = (self.ts[i], self.ts[i + 1]);
        let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
        let lam = self.sched.lambda(t);
        // x0 prediction at the current point
        let x0 = x.zip(eps, |xv, e| (xv - (s as f32) * e) / (a as f32));

        if t_next <= 0.0 {
            // final step: jump straight to the predicted x0
            self.push_history(x0.clone(), lam);
            return x0;
        }
        let an = self.sched.alpha(t_next);
        let sn = self.sched.sigma(t_next);
        let lam_next = self.sched.lambda(t_next);
        let h = lam_next - lam; // > 0 (lambda rises as t falls)

        let order = match self.kind {
            SolverKind::DpmPP2M => 2,
            SolverKind::DpmPP3M { .. } => 3,
            _ => unreachable!(),
        };
        let sde = matches!(self.kind, SolverKind::DpmPP3M { sde: true });

        if order >= 3 && self.history.len() >= 2 {
            let h_prev = lam - self.lambda_history[0];
            let r0 = (h_prev / h).max(1e-8);
            let h_prev2 = self.lambda_history[0] - self.lambda_history[1];
            let r1 = (h_prev2 / h).max(1e-8);
            let m1 = &self.history[0];
            let m2 = &self.history[1];
            // third-order correction (diffusers-style multistep)
            let d1_0 = x0.zip(m1, |c, p| (c - p) / r0 as f32);
            let d1_1 = m1.zip(m2, |c, p| (c - p) / r1 as f32);
            let frac = (r0 / (r0 + r1)) as f32;
            let d1 = d1_0.zip(&d1_1, |u, v| u + frac * (u - v));
            let d2 = d1_0.zip(&d1_1, |u, v| (u - v) / (r0 + r1) as f32);
            let phi1 = (-h).exp_m1(); // e^{-h} - 1 (< 0)
            let phi2 = phi1 / h + 1.0;
            let phi3 = phi2 / h - 0.5;
            let mut out = x.scale((sn / s) as f32);
            out.axpy(&x0, (-(an) * phi1) as f32);
            out.axpy(&d1, (-(an) * phi2) as f32);
            out.axpy(&d2, (-(an) * phi3) as f32);
            self.push_history(x0, lam);
            return self.maybe_churn(out, sn, h, sde, rng);
        }

        // Effective data estimate D from multistep history (2nd order).
        let d = if order >= 2 && !self.history.is_empty() {
            let h_prev = lam - self.lambda_history[0];
            let r0 = (h_prev / h).max(1e-8);
            let m1 = &self.history[0];
            let w = (1.0 + 1.0 / (2.0 * r0)) as f32;
            x0.zip(m1, |c, p| w * c + (1.0 - w) * p)
        } else {
            x0.clone()
        };

        let phi1 = (-h).exp_m1();
        let mut out = x.scale((sn / s) as f32);
        out.axpy(&d, (-(an) * phi1) as f32);
        self.push_history(x0, lam);
        self.maybe_churn(out, sn, h, sde, rng)
    }

    fn maybe_churn(&self, mut out: Tensor, sn: f64, h: f64, sde: bool, rng: &mut Rng) -> Tensor {
        if sde {
            // SDE variant: inject fresh noise with matched marginal scale
            // (Karras-style churn at half strength).
            let churn = (sn * (1.0 - (-2.0 * h).exp()).max(0.0).sqrt() * 0.5) as f32;
            if churn > 0.0 {
                for v in &mut out.data {
                    *v += churn * rng.normal_f32();
                }
            }
        }
        out
    }

    fn push_history(&mut self, x0: Tensor, lam: f64) {
        self.history.insert(0, x0);
        self.lambda_history.insert(0, lam);
        self.history.truncate(2);
        self.lambda_history.truncate(2);
    }
}

/// Classifier-free guidance merge: `uncond + scale · (cond − uncond)`.
pub fn cfg_merge(cond: &Tensor, uncond: &Tensor, scale: f32) -> Tensor {
    uncond.zip(cond, |u, c| u + scale * (c - u))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Analytic eps model for Gaussian data x0 ~ N(mu, s2·I) under the
    /// DDPM forward process: E[eps | x_t] is linear in x_t.
    struct GaussianEps {
        mu: f32,
        s2: f64,
        sched: LinearBeta,
    }

    impl GaussianEps {
        fn eps(&self, x: &Tensor, t: f64) -> Tensor {
            let a = self.sched.alpha(t);
            let sg = self.sched.sigma(t);
            let denom = a * a * self.s2 + sg * sg;
            x.map(|xv| ((sg / denom) as f32) * (xv - (a as f32) * self.mu))
        }
    }

    /// Analytic RF velocity for Gaussian data on the linear path
    /// x_t = (1-t)·x0 + t·e:  v = E[e − x0 | x_t].
    struct GaussianVel {
        mu: f32,
        s2: f64,
    }

    impl GaussianVel {
        fn vel(&self, x: &Tensor, t: f64) -> Tensor {
            let c = 1.0 - t;
            let var = c * c * self.s2 + t * t;
            x.map(|xv| {
                let z = xv - (c as f32) * self.mu;
                let e = (t / var) as f32 * z;
                let x0 = self.mu + ((c * self.s2 / var) as f32) * z;
                e - x0
            })
        }
    }

    fn terminal_stats(kind: SolverKind, steps: usize, mu: f32, s2: f64, n: usize) -> (f64, f64) {
        let mut rng = Rng::new(99);
        let eps_model = GaussianEps { mu, s2, sched: LinearBeta::new() };
        let vel_model = GaussianVel { mu, s2 };
        let mut all = Vec::with_capacity(n * 8);
        for _ in 0..n {
            let mut run = SolverRun::new(kind, steps);
            let mut x = SolverRun::init_latent(vec![8], &mut rng);
            for i in 0..run.steps() {
                let t = run.model_t(i);
                let out = match kind.prediction() {
                    Prediction::Epsilon => eps_model.eps(&x, t),
                    Prediction::Velocity => vel_model.vel(&x, t),
                };
                x = run.step(i, &x, &out, &mut rng);
            }
            all.extend(x.data.iter().map(|&v| v as f64));
        }
        let m = all.iter().sum::<f64>() / all.len() as f64;
        let v = all.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / all.len() as f64;
        (m, v)
    }

    #[test]
    fn ddim_recovers_gaussian() {
        let (m, v) = terminal_stats(SolverKind::Ddim, 50, 2.0, 0.25, 300);
        assert!((m - 2.0).abs() < 0.1, "mean={m}");
        assert!((v - 0.25).abs() < 0.08, "var={v}");
    }

    #[test]
    fn ddpm_ancestral_recovers_gaussian() {
        let (m, v) = terminal_stats(SolverKind::DdpmAncestral, 100, -1.0, 0.5, 300);
        assert!((m + 1.0).abs() < 0.15, "mean={m}");
        assert!((v - 0.5).abs() < 0.15, "var={v}");
    }

    #[test]
    fn dpmpp2m_recovers_gaussian() {
        let (m, v) = terminal_stats(SolverKind::DpmPP2M, 20, 1.5, 0.09, 300);
        assert!((m - 1.5).abs() < 0.1, "mean={m}");
        assert!((v - 0.09).abs() < 0.06, "var={v}");
    }

    #[test]
    fn dpmpp3m_recovers_gaussian() {
        let (m, v) = terminal_stats(SolverKind::DpmPP3M { sde: false }, 20, 0.5, 1.0, 300);
        assert!((m - 0.5).abs() < 0.12, "mean={m}");
        assert!((v - 1.0).abs() < 0.3, "var={v}");
    }

    #[test]
    fn dpmpp3m_sde_recovers_gaussian_mean() {
        let (m, _v) = terminal_stats(SolverKind::DpmPP3M { sde: true }, 50, 0.8, 0.25, 300);
        assert!((m - 0.8).abs() < 0.15, "mean={m}");
    }

    #[test]
    fn rectified_flow_recovers_gaussian() {
        let (m, v) = terminal_stats(SolverKind::RectifiedFlow, 50, 1.0, 0.16, 300);
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
        assert!((v - 0.16).abs() < 0.08, "var={v}");
    }

    #[test]
    fn dpmpp_fewer_steps_close_to_many_steps_ddim() {
        // 2nd-order with 10 steps should land near DDIM with 100 steps
        let (m10, v10) = terminal_stats(SolverKind::DpmPP2M, 10, 2.0, 0.25, 200);
        let (m100, v100) = terminal_stats(SolverKind::Ddim, 100, 2.0, 0.25, 200);
        assert!((m10 - m100).abs() < 0.12, "m10={m10} m100={m100}");
        assert!((v10 - v100).abs() < 0.1, "v10={v10} v100={v100}");
    }

    #[test]
    fn timestep_grids_are_descending_to_zero() {
        for kind in [
            SolverKind::Ddim,
            SolverKind::DdpmAncestral,
            SolverKind::DpmPP2M,
            SolverKind::DpmPP3M { sde: false },
            SolverKind::RectifiedFlow,
        ] {
            let run = SolverRun::new(kind, 30);
            assert_eq!(run.steps(), 30);
            assert_eq!(*run.ts.last().unwrap(), 0.0);
            assert!((run.ts[0] - 1.0).abs() < 1e-12);
            for w in run.ts.windows(2) {
                assert!(w[0] > w[1], "{kind:?}");
            }
        }
    }

    #[test]
    fn cfg_merge_identity_at_scale_one() {
        let c = Tensor::new(vec![3], vec![1., 2., 3.]);
        let u = Tensor::new(vec![3], vec![0., 0., 0.]);
        assert_eq!(cfg_merge(&c, &u, 1.0).data, vec![1., 2., 3.]);
        assert_eq!(cfg_merge(&c, &u, 2.0).data, vec![2., 4., 6.]);
        assert_eq!(cfg_merge(&c, &u, 0.0).data, vec![0., 0., 0.]);
    }

    #[test]
    fn solver_kind_parse_roundtrip() {
        for name in ["ddim", "ddpm", "dpmpp2m", "dpmpp3m", "dpmpp3m-sde", "rf"] {
            assert_eq!(SolverKind::parse(name).unwrap().name(), name);
        }
        assert!(SolverKind::parse("nope").is_none());
    }

    #[test]
    fn deterministic_solvers_are_deterministic() {
        for kind in [SolverKind::Ddim, SolverKind::DpmPP2M, SolverKind::RectifiedFlow] {
            let run_one = |seed: u64| {
                let mut rng = Rng::new(seed);
                let model = GaussianEps { mu: 0.0, s2: 1.0, sched: LinearBeta::new() };
                let vel = GaussianVel { mu: 0.0, s2: 1.0 };
                let mut run = SolverRun::new(kind, 10);
                let mut x = SolverRun::init_latent(vec![4], &mut rng);
                for i in 0..run.steps() {
                    let t = run.model_t(i);
                    let out = match kind.prediction() {
                        Prediction::Epsilon => model.eps(&x, t),
                        Prediction::Velocity => vel.vel(&x, t),
                    };
                    x = run.step(i, &x, &out, &mut rng);
                }
                x
            };
            assert_eq!(run_one(5).data, run_one(5).data, "{kind:?}");
        }
    }
}
