//! Workload generation: Poisson arrivals, conditioning samplers and
//! trace record/replay for the serving benches (the paper measures
//! steady-state latency; the e2e bench adds open-loop arrivals).

use crate::model::Cond;
use crate::util::rng::Rng;

/// One request in a workload trace.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// arrival offset from trace start, seconds.
    pub arrival_s: f64,
    pub cond: Cond,
    pub seed: u64,
}

/// Open-loop Poisson arrival trace over random conditionings.
pub struct PoissonTrace {
    pub items: Vec<TraceItem>,
}

impl PoissonTrace {
    /// `rate_rps` requests/second for `n` requests.
    pub fn generate(
        rate_rps: f64,
        n: usize,
        num_classes: usize,
        vocab: usize,
        cond_len: usize,
        seed: u64,
    ) -> PoissonTrace {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut items = Vec::with_capacity(n);
        for i in 0..n {
            t += rng.exponential(rate_rps);
            let cond = crate::cache::sample_cond(&mut rng, num_classes, vocab, cond_len, false);
            items.push(TraceItem { arrival_s: t, cond, seed: seed ^ (i as u64) << 17 });
        }
        PoissonTrace { items }
    }

    pub fn duration(&self) -> f64 {
        self.items.last().map(|i| i.arrival_s).unwrap_or(0.0)
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_rate_approximates() {
        let tr = PoissonTrace::generate(20.0, 2000, 10, 0, 0, 1);
        assert_eq!(tr.len(), 2000);
        let measured = tr.len() as f64 / tr.duration();
        assert!((measured - 20.0).abs() < 2.0, "rate={measured}");
        // arrivals strictly increasing
        for w in tr.items.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    #[test]
    fn trace_conditioning_matches_family_kind() {
        let labels = PoissonTrace::generate(1.0, 10, 10, 0, 0, 2);
        assert!(labels.items.iter().all(|i| matches!(i.cond, Cond::Label(_))));
        let prompts = PoissonTrace::generate(1.0, 10, 0, 256, 8, 3);
        assert!(prompts
            .items
            .iter()
            .all(|i| matches!(&i.cond, Cond::Prompt(p) if p.len() == 8)));
    }

    #[test]
    fn trace_deterministic() {
        let a = PoissonTrace::generate(5.0, 50, 10, 0, 0, 9);
        let b = PoissonTrace::generate(5.0, 50, 10, 0, 0, 9);
        assert_eq!(a.items.len(), b.items.len());
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.cond, y.cond);
        }
    }
}
