//! `smoothcache` — the serving launcher.
//!
//! Subcommands:
//!   serve      start the TCP serving stack (coordinator + server)
//!   generate   one-off generation from the CLI
//!   calibrate  run a calibration pass, save curves JSON
//!   schedule   print the schedule a policy resolves to
//!   trace      dump a server's flight recorder as a timeline
//!   info       artifact/manifest inventory
//!
//! Run `smoothcache <subcommand> --help` for flags.

use std::sync::Arc;
use std::time::Duration;

use smoothcache::util::error::{Error, Result};
use smoothcache::cache::{calibrate, CalibrationConfig};
use smoothcache::coordinator::{
    Coordinator, CoordinatorConfig, Deadline, DeadlinePolicy, Policy, Request, SubmitOpts,
};
use smoothcache::model::{Cond, Engine, Manifest};
use smoothcache::server::Server;
use smoothcache::solvers::SolverKind;
use smoothcache::util::cli::CliSpec;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    let result = match cmd {
        "serve" => cmd_serve(&rest),
        "generate" => cmd_generate(&rest),
        "calibrate" => cmd_calibrate(&rest),
        "schedule" => cmd_schedule(&rest),
        "trace" => cmd_trace(&rest),
        "info" => cmd_info(&rest),
        _ => {
            eprintln!(
                "smoothcache — SmoothCache serving stack\n\n\
                 usage: smoothcache <serve|generate|calibrate|schedule|trace|info> [flags]\n\
                 examples:\n  \
                 smoothcache serve --addr 127.0.0.1:7878 --preload image --workers 2 --threads 4\n  \
                 smoothcache generate --family image --label 3 --policy smooth:0.35\n  \
                 smoothcache calibrate --family audio --solver dpmpp3m-sde --steps 100\n  \
                 smoothcache schedule --family image --steps 50 --policy fora:2\n  \
                 smoothcache trace --addr 127.0.0.1:7878 --chrome trace.json\n  \
                 smoothcache info"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_or_usage(spec: CliSpec, argv: &[String]) -> Result<Option<smoothcache::util::cli::ParsedArgs>> {
    match spec.parse(argv) {
        Ok(a) => Ok(Some(a)),
        Err(usage) => {
            eprintln!("{usage}");
            Ok(None)
        }
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let spec = CliSpec::new("smoothcache serve", "start the serving stack")
        .flag("addr", "127.0.0.1:7878", "listen address")
        .flag("preload", "image", "families to preload (comma list)")
        .flag("max-wait-ms", "20", "batcher flush deadline")
        .flag("calib-samples", "6", "calibration samples for smooth policies")
        .flag("curves-dir", "", "directory of pre-computed calibration curves")
        .flag("workers", "2", "executor replicas (backend engines; PJRT clamps to 1)")
        .flag("queue-depth", "256", "max requests waiting in the shared work queue before admission rejects with an overloaded error")
        .flag("threads", "0", "GEMM compute threads per process (0 = auto)")
        .flag("conn-threads", "4", "connection handler threads")
        .flag("conn-inflight", "32", "protocol v2 per-connection credit window: concurrent generations one connection may hold in flight")
        .flag("idle-timeout-s", "60", "protocol v2 idle-connection reaper: ping then close after this many idle seconds (0 = never)")
        .bool_flag("v2", "accept only framed v2 (SMC2) connections; refuse v1 JSON-lines");
    let Some(args) = parse_or_usage(spec, argv)? else { return Ok(()) };

    let threads = args.usize("threads").map_err(Error::msg)?;
    if threads > 0 {
        smoothcache::tensor::gemm::set_threads(threads);
    }
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = args.list("preload");
    cfg.max_wait = Duration::from_millis(args.u64("max-wait-ms").map_err(Error::msg)?);
    cfg.calib_samples = args.usize("calib-samples").map_err(Error::msg)?;
    cfg.workers = args.usize("workers").map_err(Error::msg)?.max(1);
    cfg.queue_depth = args.usize("queue-depth").map_err(Error::msg)?.max(1);
    if !args.str("curves-dir").is_empty() {
        cfg.curves_dir = Some(args.string("curves-dir").into());
    }
    let queue_depth = cfg.queue_depth;
    let coord = Arc::new(Coordinator::start(cfg)?);
    let opts = smoothcache::server::ServerOpts {
        conn_threads: args.usize("conn-threads").map_err(Error::msg)?,
        conn_inflight: args.usize("conn-inflight").map_err(Error::msg)?.max(1),
        idle_timeout: Duration::from_secs(args.u64("idle-timeout-s").map_err(Error::msg)?),
        v2_only: args.bool("v2"),
        ..smoothcache::server::ServerOpts::default()
    };
    let conn_inflight = opts.conn_inflight;
    let v2_only = opts.v2_only;
    let server = Server::start_with(args.str("addr"), Arc::clone(&coord), opts)?;
    println!(
        "smoothcache serving on {} (workers={}, threads={}, queue-depth={}, conn-inflight={})",
        server.addr,
        smoothcache::coordinator::Metrics::get(&coord.metrics().executor_replicas).max(1),
        smoothcache::tensor::gemm::threads(),
        queue_depth,
        conn_inflight
    );
    if v2_only {
        println!("protocol: framed v2 only (SMC2 preamble; docs/protocol.md §Protocol v2)");
    } else {
        println!(
            "protocol: one JSON object per line (try {{\"cmd\": \"ping\"}}), \
             or framed v2 via the SMC2 preamble"
        );
    }
    // serve until killed
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let spec = CliSpec::new("smoothcache generate", "one-off generation")
        .flag("family", "image", "model family")
        .flag("label", "0", "class label (image family)")
        .flag("prompt-ids", "", "comma-separated prompt token ids (audio/video)")
        .flag("solver", "ddim", "solver")
        .flag("steps", "50", "sampling steps")
        .flag("cfg", "1.0", "CFG scale")
        .flag("seed", "0", "random seed")
        .flag("policy", "no-cache", "caching policy (no-cache|fora:N|alternate|smooth:A|drift:B; table: smoothcache info)")
        .flag("compute", "f32", "weight-matmul precision (f32|f16|bf16|int8)")
        .flag("calib-samples", "6", "calibration samples for smooth policies")
        .flag("workers", "1", "executor replicas (one is plenty for a one-off)")
        .flag("threads", "0", "GEMM compute threads (0 = auto)")
        .flag("deadline-ms", "0", "latency deadline in ms (0 = none)")
        .flag("deadline-policy", "best-effort", "what to do with late work: best-effort|reject")
        .flag("priority", "interactive", "scheduling class: interactive|batch (batch yields to interactive work)")
        .bool_flag("stream", "print one progress line per solver step")
        .flag("out", "", "write latent to this path (JSON)")
        .flag("connect", "", "send the request to a running server at this address instead of generating in-process")
        .bool_flag("v2", "with --connect: use the framed v2 protocol (multiplexing Client2) instead of v1 JSON-lines");
    let Some(args) = parse_or_usage(spec, argv)? else { return Ok(()) };

    if !args.str("connect").is_empty() {
        return remote_generate(&args);
    }
    if args.bool("v2") {
        return Err(smoothcache::err!("--v2 needs --connect ADDR (it selects the wire protocol)"));
    }

    let threads = args.usize("threads").map_err(Error::msg)?;
    if threads > 0 {
        smoothcache::tensor::gemm::set_threads(threads);
    }
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec![args.string("family")];
    cfg.calib_samples = args.usize("calib-samples").map_err(Error::msg)?;
    cfg.workers = args.usize("workers").map_err(Error::msg)?.max(1);
    let coord = Coordinator::start(cfg)?;

    let cond = if args.str("prompt-ids").is_empty() {
        Cond::Label(vec![args.usize("label").map_err(Error::msg)? as i32])
    } else {
        Cond::Prompt(
            args.usize_list("prompt-ids")
                .map_err(Error::msg)?
                .into_iter()
                .map(|v| v as i32)
                .collect(),
        )
    };
    let request = Request {
        id: 0,
        family: args.string("family"),
        cond,
        solver: SolverKind::parse(args.str("solver")).ok_or_else(|| smoothcache::err!("bad solver"))?,
        steps: args.usize("steps").map_err(Error::msg)?,
        cfg_scale: args.f64("cfg").map_err(Error::msg)? as f32,
        seed: args.u64("seed").map_err(Error::msg)?,
        policy: Policy::parse(args.str("policy"))?,
        compute: smoothcache::tensor::ComputeMode::parse(args.str("compute"))?,
        priority: smoothcache::coordinator::PriorityClass::parse(args.str("priority"))
            .ok_or_else(|| smoothcache::err!("--priority: interactive or batch"))?,
    };
    let deadline = match args.u64("deadline-ms").map_err(Error::msg)? {
        0 => None,
        ms => {
            let policy = DeadlinePolicy::parse(args.str("deadline-policy"))
                .ok_or_else(|| smoothcache::err!("--deadline-policy: best-effort or reject"))?;
            Some(Deadline::after(Duration::from_millis(ms), policy))
        }
    };
    let (progress, progress_rx) = if args.bool("stream") {
        let (tx, rx) = std::sync::mpsc::channel();
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };
    let ticket =
        coord.submit_opts(request, SubmitOpts { progress, deadline, trace: Default::default() });
    let print_progress = |rx: &std::sync::mpsc::Receiver<smoothcache::coordinator::Progress>| {
        while let Ok(p) = rx.try_recv() {
            println!(
                "step {:>4}/{} computes={} reuses={} t={:.3}s",
                p.step + 1,
                p.steps,
                p.computes,
                p.reuses,
                p.elapsed_s
            );
        }
    };
    let resp = loop {
        if let Some(rx) = &progress_rx {
            print_progress(rx);
        }
        match ticket.reply.recv_timeout(Duration::from_millis(25)) {
            Ok(r) => break r?,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                return Err(smoothcache::err!("coordinator shut down"));
            }
        }
    };
    // drain the step lines that raced the final reply (the executor
    // sends every progress event before the response)
    if let Some(rx) = &progress_rx {
        print_progress(rx);
    }
    println!(
        "generated {:?} in {:.3}s (exec {:.3}s, batch {}, skips {:.0}%)",
        resp.latent.shape,
        resp.total_seconds,
        resp.exec_seconds,
        resp.batch_size,
        resp.gen_stats.skip_fraction() * 100.0
    );
    if resp.deadline_missed {
        eprintln!("warning: best-effort deadline missed ({:.3}s total)", resp.total_seconds);
    }
    if !args.str("out").is_empty() {
        let j = smoothcache::util::json::Json::obj()
            .set(
                "shape",
                resp.latent.shape.iter().map(|&d| d as f64).collect::<Vec<_>>(),
            )
            .set(
                "data",
                resp.latent.data.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            );
        std::fs::write(args.str("out"), j.to_string())?;
        println!("latent written to {}", args.str("out"));
    }
    coord.shutdown();
    Ok(())
}

/// `generate --connect ADDR [--v2]`: ship the request to a running
/// server over v1 JSON-lines ([`smoothcache::server::Client`]) or the
/// framed v2 protocol ([`smoothcache::server::Client2`]).
fn remote_generate(args: &smoothcache::util::cli::ParsedArgs) -> Result<()> {
    use smoothcache::util::json::Json;

    let addr: std::net::SocketAddr = args
        .str("connect")
        .parse()
        .map_err(|e| smoothcache::err!("--connect {:?}: {e}", args.str("connect")))?;
    let mut req = Json::obj()
        .set("family", args.string("family"))
        .set("solver", args.string("solver"))
        .set("steps", args.usize("steps").map_err(Error::msg)?)
        .set("cfg", args.f64("cfg").map_err(Error::msg)?)
        .set("seed", args.u64("seed").map_err(Error::msg)?)
        .set("policy", args.string("policy"))
        .set("compute", args.string("compute"))
        .set("priority", args.string("priority"));
    if args.str("prompt-ids").is_empty() {
        req = req.set("label", args.usize("label").map_err(Error::msg)?);
    } else {
        req = req.set("prompt_ids", args.usize_list("prompt-ids").map_err(Error::msg)?);
    }
    match args.u64("deadline-ms").map_err(Error::msg)? {
        0 => {}
        ms => {
            req = req
                .set("deadline_ms", ms)
                .set("deadline_policy", args.string("deadline-policy"));
        }
    }
    if !args.str("out").is_empty() {
        req = req.set("return_latent", true);
    }
    let on_event = |ev: &Json| match ev.get("event").and_then(|v| v.as_str()) {
        Some("accepted") => {
            if let Some(id) = ev.get("id").and_then(|v| v.as_u64()) {
                println!("accepted id={id}");
            }
        }
        _ => println!(
            "step {:>4}/{} computes={} reuses={} t={:.3}s",
            ev.get("step").and_then(|v| v.as_u64()).unwrap_or(0) + 1,
            ev.get("steps").and_then(|v| v.as_u64()).unwrap_or(0),
            ev.get("computes").and_then(|v| v.as_u64()).unwrap_or(0),
            ev.get("reuses").and_then(|v| v.as_u64()).unwrap_or(0),
            ev.get("t_s").and_then(|v| v.as_f64()).unwrap_or(0.0)
        ),
    };
    let reply = if args.bool("v2") {
        let client = smoothcache::server::Client2::connect(&addr)?;
        if args.bool("stream") {
            client.call_streaming(&req, on_event)?
        } else {
            client.call(&req)?
        }
    } else {
        let mut client = smoothcache::server::Client::connect(&addr)?;
        if args.bool("stream") {
            client.call_streaming(&req, on_event)?
        } else {
            client.call(&req)?
        }
    };
    if reply.get("ok").and_then(|v| v.as_bool()) != Some(true) {
        let msg = reply.get("error").and_then(|v| v.as_str()).unwrap_or("unknown server error");
        return Err(smoothcache::err!("server: {msg}"));
    }
    println!(
        "generated {:?} in {:.3}s (exec {:.3}s, batch {}, skips {:.0}%) via {}",
        reply.get("latent_shape").and_then(|v| v.as_usize_vec()).unwrap_or_default(),
        reply.get("total_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
        reply.get("exec_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
        reply.get("batch_size").and_then(|v| v.as_u64()).unwrap_or(0),
        reply.get("skip_fraction").and_then(|v| v.as_f64()).unwrap_or(0.0) * 100.0,
        if args.bool("v2") { "v2" } else { "v1" }
    );
    if !args.str("out").is_empty() {
        let shape = reply.get("latent_shape").and_then(|v| v.as_f64_vec()).unwrap_or_default();
        let data = reply
            .get("latent")
            .and_then(|v| v.as_f64_vec())
            .ok_or_else(|| smoothcache::err!("server reply carried no latent"))?;
        let j = Json::obj().set("shape", shape).set("data", data);
        std::fs::write(args.str("out"), j.to_string())?;
        println!("latent written to {}", args.str("out"));
    }
    Ok(())
}

fn cmd_calibrate(argv: &[String]) -> Result<()> {
    let spec = CliSpec::new("smoothcache calibrate", "run a calibration pass")
        .flag("family", "image", "model family")
        .flag("solver", "ddim", "solver")
        .flag("steps", "50", "sampling steps")
        .flag("samples", "10", "calibration samples")
        .flag("k-max", "3", "maximum reuse gap")
        .flag("cfg", "1.0", "CFG scale during calibration")
        .flag("out", "artifacts/calibration", "output directory");
    let Some(args) = parse_or_usage(spec, argv)? else { return Ok(()) };

    let family = args.string("family");
    let mut engine = Engine::open(smoothcache::artifacts_dir())?;
    engine.load_family(&family)?;
    let solver = SolverKind::parse(args.str("solver")).ok_or_else(|| smoothcache::err!("bad solver"))?;
    let cc = CalibrationConfig {
        solver,
        steps: args.usize("steps").map_err(Error::msg)?,
        k_max: args.usize("k-max").map_err(Error::msg)?,
        num_samples: args.usize("samples").map_err(Error::msg)?,
        cfg_scale: args.f64("cfg").map_err(Error::msg)? as f32,
        seed: 7,
    };
    let t0 = std::time::Instant::now();
    let curves = calibrate(&engine, &family, &cc)?;
    let out = args.string("out");
    std::fs::create_dir_all(&out)?;
    let path = format!("{out}/{family}_{}_{}.json", solver.name(), cc.steps);
    std::fs::write(&path, curves.to_json().to_string())?;
    println!(
        "calibrated {} samples in {:.1}s → {path}",
        cc.num_samples,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_schedule(argv: &[String]) -> Result<()> {
    let spec = CliSpec::new("smoothcache schedule", "print a resolved cache plan")
        .flag("family", "image", "model family")
        .flag("solver", "ddim", "solver")
        .flag("steps", "50", "sampling steps")
        .flag("policy", "smooth:0.35", "caching policy (table: smoothcache info)")
        .flag("calib-samples", "6", "calibration samples if needed");
    let Some(args) = parse_or_usage(spec, argv)? else { return Ok(()) };

    let family = args.string("family");
    let mut engine = Engine::open(smoothcache::artifacts_dir())?;
    engine.load_family(&family)?;
    let solver = SolverKind::parse(args.str("solver")).ok_or_else(|| smoothcache::err!("bad solver"))?;
    let steps = args.usize("steps").map_err(Error::msg)?;
    let policy = Policy::parse(args.str("policy"))?;
    if policy.planner().dynamic().is_some() {
        println!(
            "{}: runtime-adaptive policy — decisions are made per (step, site) \
             from the observed trajectory; there is no static plan to print",
            policy.wire()
        );
        return Ok(());
    }
    let mut store = smoothcache::coordinator::PlanStore::new(
        args.usize("calib-samples").map_err(Error::msg)?,
        7,
        None,
    );
    let plan = store.plan(&engine, None, &family, solver, steps, &policy)?;
    println!(
        "{} — {} sites, skip {:.0}%, max gap {}",
        plan.name,
        plan.n_sites(),
        plan.skip_fraction() * 100.0,
        plan.max_gap()
    );
    print!("{}", plan.ascii());
    Ok(())
}

/// `smoothcache trace`: fetch a running server's flight recorder
/// (`{"cmd":"dump"}`, docs/adr/009) and render it as a plain-text
/// timeline, or write Chrome trace-event JSON for chrome://tracing /
/// Perfetto with `--chrome PATH`.
fn cmd_trace(argv: &[String]) -> Result<()> {
    let spec = CliSpec::new("smoothcache trace", "dump a server's flight recorder")
        .flag("addr", "127.0.0.1:7878", "server address")
        .flag("last", "0", "only the most recent N timelines (0 = all retained)")
        .flag("chrome", "", "write Chrome trace-event JSON to this path instead of printing");
    let Some(args) = parse_or_usage(spec, argv)? else { return Ok(()) };

    let addr: std::net::SocketAddr = args
        .str("addr")
        .parse()
        .map_err(|e| smoothcache::err!("--addr {:?}: {e}", args.str("addr")))?;
    let mut client = smoothcache::server::Client::connect(&addr)?;
    let dump = client.dump()?;
    if dump.get("ok").and_then(|v| v.as_bool()) != Some(true) {
        let msg = dump.get("error").and_then(|v| v.as_str()).unwrap_or("unknown server error");
        return Err(smoothcache::err!("server: {msg}"));
    }
    let level = dump.get("level").and_then(|v| v.as_str()).unwrap_or("?").to_string();
    let mut entries = smoothcache::obs::export::DumpEntry::from_dump(&dump)?;
    let last = args.usize("last").map_err(Error::msg)?;
    if last > 0 && entries.len() > last {
        // dump order is oldest-first by trace id — keep the tail
        entries.drain(..entries.len() - last);
    }
    if entries.is_empty() {
        println!("flight recorder is empty (server trace level: {level})");
        return Ok(());
    }
    if !args.str("chrome").is_empty() {
        let j = smoothcache::obs::export::chrome_trace(&entries);
        std::fs::write(args.str("chrome"), j.to_string())?;
        println!(
            "{} timeline(s) written to {} (load in chrome://tracing or Perfetto)",
            entries.len(),
            args.str("chrome")
        );
    } else {
        println!("flight recorder: {} timeline(s), server trace level {level}\n", entries.len());
        print!("{}", smoothcache::obs::export::render(&entries));
    }
    Ok(())
}

fn cmd_info(_argv: &[String]) -> Result<()> {
    let dir = smoothcache::artifacts_dir();
    let (manifest, on_disk) = Manifest::load_or_builtin(&dir)?;
    println!("artifacts dir : {dir:?}{}", if on_disk { "" } else { " (none — builtin geometry)" });
    println!("kernel impl   : {}", manifest.impl_name);
    println!("batch sizes   : {:?}", manifest.batch_sizes);
    println!("\ncaching policies (wire syntax — the registry the server and CLI share):");
    for spec in smoothcache::cache::registry() {
        let kind = if spec.dynamic {
            "dynamic"
        } else if spec.needs_curves {
            "calibrated"
        } else {
            "static"
        };
        println!("  {:>22}  [{kind:^10}]  {}", spec.syntax, spec.summary);
    }
    for (name, fm) in &manifest.families {
        println!(
            "\nfamily {name}: hidden={} heads={} depth={} seq={} latent={:?}",
            fm.hidden, fm.heads, fm.depth, fm.seq_len, fm.latent_shape
        );
        println!("  branch types: {:?}", fm.branch_types);
        println!("  entries: {}", fm.entries.len());
        println!(
            "  forward GMACs: {:.4} (cacheable {:.1}%)",
            smoothcache::macs::as_gmacs(smoothcache::macs::forward_macs(fm)),
            smoothcache::macs::cacheable_fraction(fm) * 100.0
        );
    }
    Ok(())
}
