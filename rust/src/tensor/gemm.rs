//! Cache-blocked, threadpool-parallel f32 GEMM (the shared parallel
//! compute substrate) with a runtime-dispatched SIMD microkernel.
//!
//! Every reference-backend matmul — token/QKV/output projections, the
//! FFN, the per-head score/value products inside attention — routes
//! through [`matmul`] / [`matmul_bt`]. Work is split into row panels
//! and fanned out over a process-wide [`ThreadPool`] via
//! [`ThreadPool::scoped_map`]; inside a panel the k-dimension is walked
//! in fixed-size blocks so a `KC x n` slab of `w` stays hot in cache
//! across the panel's rows.
//!
//! **Determinism contract:** for a given output element the f32
//! accumulation order is ascending `k`, one term at a time, regardless
//! of thread count, panel boundaries, k-blocking *or kernel choice* —
//! so results are *bitwise identical* across `--threads` settings,
//! equal to the naive serial triple loop, and identical between the
//! scalar and SIMD kernels. `tests/parallel_parity.rs` and CI
//! (`SMOOTHCACHE_THREADS=1` vs `4`, `SMOOTHCACHE_FORCE_SCALAR=1` vs
//! auto) lock this in; caching decisions must never depend on
//! parallelism or on which kernel dispatched.
//!
//! **Kernel dispatch** (see docs/adr/006): the SIMD microkernels
//! vectorise across output *columns* — every lane performs the same
//! multiply-then-add sequence in ascending `ki` that the scalar kernel
//! performs for that element, and FMA is deliberately not used (a fused
//! single-rounding multiply-add would diverge from the scalar two-
//! rounding sequence). That makes runtime feature detection safe: the
//! choice of kernel is a pure performance decision, never a numerics
//! one, and the scalar kernel stays the always-available parity
//! reference. Resolution order (first match wins):
//! 1. a [`with_kernel`] scope on the calling thread,
//! 2. the `SMOOTHCACHE_FORCE_SCALAR` environment variable (any value
//!    except `0`/empty forces [`Kernel::Scalar`]),
//! 3. auto: AVX2 on x86_64 when detected, NEON on aarch64, else scalar.
//!
//! Thread-count resolution (first match wins):
//! 1. a [`with_threads`] scope on the calling thread,
//! 2. the process-wide count from [`set_threads`] (the `--threads`
//!    CLI knob),
//! 3. the `SMOOTHCACHE_THREADS` environment variable,
//! 4. `available_parallelism()` capped at 8.
//!
//! Calls issued *from* a pool worker (nested parallelism) degrade to
//! inline serial execution instead of deadlocking — see
//! [`on_worker_thread`].

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::threadpool::{on_worker_thread, ThreadPool};

/// k-dimension block: a `KC x n` slab of `w` (`KC x 512` f32 = 256 KiB
/// at the largest builtin width) is reused across every row of a panel
/// before the walk advances. Public so shape-coverage tests can probe
/// the `k < KC` / `k > KC` boundary deliberately.
pub const KC: usize = 128;

/// Below this many multiply-accumulates a GEMM runs inline: job
/// dispatch over the channel-based pool costs more than it buys.
const MIN_PAR_MACS: usize = 64 * 1024;

// ---------------------------------------------------------------------------
// Thread-count configuration
// ---------------------------------------------------------------------------

/// Process-wide thread count; 0 = not yet resolved.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_threads`]; 0 = none.
    static TL_THREADS: Cell<usize> = const { Cell::new(0) };
}

fn env_or_auto_threads() -> usize {
    std::env::var("SMOOTHCACHE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        })
}

/// Set the process-wide compute thread count (the `--threads` knob).
/// Takes effect for every subsequent GEMM on any thread without an
/// active [`with_threads`] scope.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::SeqCst);
}

/// The thread count the next GEMM on this thread will use.
pub fn threads() -> usize {
    let tl = TL_THREADS.with(|c| c.get());
    if tl > 0 {
        return tl;
    }
    let g = GLOBAL_THREADS.load(Ordering::SeqCst);
    if g > 0 {
        return g;
    }
    let resolved = env_or_auto_threads();
    // benign race: every contender resolves the same value
    let _ = GLOBAL_THREADS.compare_exchange(0, resolved, Ordering::SeqCst, Ordering::SeqCst);
    GLOBAL_THREADS.load(Ordering::SeqCst)
}

/// Run `f` with this thread's GEMM thread count pinned to `n`
/// (restored afterwards, panic-safe). The parity tests sweep thread
/// counts with this without perturbing other test threads.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_THREADS.with(|c| c.set(self.0));
        }
    }
    let prev = TL_THREADS.with(|c| c.replace(n.max(1)));
    let _restore = Restore(prev);
    f()
}

/// Process-wide pool registry, one pool per size. Pools live for the
/// process lifetime; the handful of sizes in play (CLI value, test
/// sweep values) bounds the registry.
fn pool_for(n: usize) -> Arc<ThreadPool> {
    static POOLS: OnceLock<Mutex<HashMap<usize, Arc<ThreadPool>>>> = OnceLock::new();
    let pools = POOLS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = pools.lock().unwrap();
    Arc::clone(guard.entry(n).or_insert_with(|| Arc::new(ThreadPool::new(n))))
}

// ---------------------------------------------------------------------------
// Kernel selection
// ---------------------------------------------------------------------------

/// Which panel kernel a GEMM dispatches to. Both choices produce
/// bitwise-identical results (see the module docs); `Scalar` exists so
/// tests and CI can pin the reference implementation.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kernel {
    /// Runtime-detected SIMD microkernel when available, scalar
    /// otherwise.
    Auto,
    /// The scalar reference kernel, unconditionally.
    Scalar,
}

thread_local! {
    /// Per-thread override installed by [`with_kernel`]; `None` = defer
    /// to the environment / auto detection.
    static TL_KERNEL: Cell<Option<Kernel>> = const { Cell::new(None) };
}

fn env_force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var("SMOOTHCACHE_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// The kernel choice the next GEMM on this thread will use.
pub fn kernel() -> Kernel {
    if let Some(k) = TL_KERNEL.with(|c| c.get()) {
        return k;
    }
    if env_force_scalar() {
        Kernel::Scalar
    } else {
        Kernel::Auto
    }
}

/// Run `f` with this thread's kernel choice pinned (restored
/// afterwards, panic-safe). An explicit scope outranks the
/// `SMOOTHCACHE_FORCE_SCALAR` environment knob so the parity suite can
/// compare both kernels in either CI lane.
pub fn with_kernel<R>(kind: Kernel, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Kernel>);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_KERNEL.with(|c| c.set(self.0));
        }
    }
    let prev = TL_KERNEL.with(|c| c.replace(Some(kind)));
    let _restore = Restore(prev);
    f()
}

/// Whether a SIMD microkernel exists for this CPU. Detection runs once;
/// the answer never affects results, only speed.
fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static S: OnceLock<bool> = OnceLock::new();
        return *S.get_or_init(avx2::available);
    }
    #[cfg(target_arch = "aarch64")]
    {
        return true; // NEON is baseline on aarch64
    }
    #[allow(unreachable_code)]
    false
}

fn use_simd() -> bool {
    kernel() == Kernel::Auto && simd_supported()
}

/// Name of the kernel the next GEMM on this thread will dispatch to
/// (`"avx2"` | `"neon"` | `"scalar"`) — introspection for bench
/// metadata and logs.
pub fn active_kernel_name() -> &'static str {
    if !use_simd() {
        return "scalar";
    }
    #[cfg(target_arch = "x86_64")]
    {
        return "avx2";
    }
    #[cfg(target_arch = "aarch64")]
    {
        return "neon";
    }
    #[allow(unreachable_code)]
    "scalar"
}

// ---------------------------------------------------------------------------
// Scalar panel kernels (the parity reference)
// ---------------------------------------------------------------------------

/// `out[rows, n] = x[rows, k] @ w[k, n] (+ bias)`, k-blocked, axpy form:
/// each output row accumulates terms in ascending `k`, one at a time.
fn gemm_panel_scalar(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(x.len(), rows * k);
    for r in 0..rows {
        let orow = &mut out[r * n..(r + 1) * n];
        match bias {
            Some(b) => orow.copy_from_slice(b),
            None => orow.fill(0.0),
        }
    }
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KC).min(k);
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            for ki in k0..kend {
                let xv = xrow[ki];
                let wrow = &w[ki * n..(ki + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        k0 = kend;
    }
}

/// `out[rows, n] = x[rows, k] @ wt[n, k]^T (+ bias)` — transposed-B
/// variant (each output element is a running dot over ascending `k`).
fn gemm_bt_panel_scalar(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    wt: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(x.len(), rows * k);
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let wrow = &wt[j * k..(j + 1) * k];
            let mut acc = match bias {
                Some(b) => b[j],
                None => 0.0,
            };
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += xv * wv;
            }
            *o = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 microkernel (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! 4-row by 16-column register-tiled microkernel. Lanes run across
    //! output columns, so each lane executes the exact scalar sequence
    //! for its element: load w once per 4 rows, broadcast x, multiply,
    //! then add (two roundings — never FMA). Accumulators live in ymm
    //! registers across a whole k-block; the intermediate loads/stores
    //! of `out` between blocks are exact and do not perturb values.

    use core::arch::x86_64::*;

    use super::KC;

    /// Row tile: accumulator rows held in registers at once.
    const MR: usize = 4;
    /// f32 lanes per ymm vector.
    const LANES: usize = 8;

    pub fn available() -> bool {
        std::is_x86_feature_detected!("avx2")
    }

    /// # Safety
    /// Caller must have verified AVX2 via [`available`]. Slice lengths
    /// must satisfy the same invariants as the scalar kernel.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_panel(
        out: &mut [f32],
        x: &[f32],
        rows: usize,
        k: usize,
        w: &[f32],
        n: usize,
        bias: Option<&[f32]>,
    ) {
        debug_assert_eq!(out.len(), rows * n);
        debug_assert_eq!(x.len(), rows * k);
        for r in 0..rows {
            let orow = &mut out[r * n..(r + 1) * n];
            match bias {
                Some(b) => orow.copy_from_slice(b),
                None => orow.fill(0.0),
            }
        }
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        let wp = w.as_ptr();
        let mut k0 = 0;
        while k0 < k {
            let kend = (k0 + KC).min(k);
            let mut r0 = 0;
            while r0 < rows {
                let mr = (rows - r0).min(MR);
                let mut j = 0;
                // 4 x 16 tile: two ymm column vectors per row
                while j + 2 * LANES <= n {
                    let mut acc0 = [_mm256_setzero_ps(); MR];
                    let mut acc1 = [_mm256_setzero_ps(); MR];
                    for ri in 0..mr {
                        let base = (r0 + ri) * n + j;
                        acc0[ri] = _mm256_loadu_ps(op.add(base));
                        acc1[ri] = _mm256_loadu_ps(op.add(base + LANES));
                    }
                    for ki in k0..kend {
                        let w0 = _mm256_loadu_ps(wp.add(ki * n + j));
                        let w1 = _mm256_loadu_ps(wp.add(ki * n + j + LANES));
                        for ri in 0..mr {
                            let xv = _mm256_set1_ps(*xp.add((r0 + ri) * k + ki));
                            acc0[ri] = _mm256_add_ps(acc0[ri], _mm256_mul_ps(xv, w0));
                            acc1[ri] = _mm256_add_ps(acc1[ri], _mm256_mul_ps(xv, w1));
                        }
                    }
                    for ri in 0..mr {
                        let base = (r0 + ri) * n + j;
                        _mm256_storeu_ps(op.add(base), acc0[ri]);
                        _mm256_storeu_ps(op.add(base + LANES), acc1[ri]);
                    }
                    j += 2 * LANES;
                }
                // one remaining full vector of columns
                while j + LANES <= n {
                    let mut acc = [_mm256_setzero_ps(); MR];
                    for ri in 0..mr {
                        acc[ri] = _mm256_loadu_ps(op.add((r0 + ri) * n + j));
                    }
                    for ki in k0..kend {
                        let wv = _mm256_loadu_ps(wp.add(ki * n + j));
                        for ri in 0..mr {
                            let xv = _mm256_set1_ps(*xp.add((r0 + ri) * k + ki));
                            acc[ri] = _mm256_add_ps(acc[ri], _mm256_mul_ps(xv, wv));
                        }
                    }
                    for ri in 0..mr {
                        _mm256_storeu_ps(op.add((r0 + ri) * n + j), acc[ri]);
                    }
                    j += LANES;
                }
                // scalar column tail (< 8 columns), same per-element order
                if j < n {
                    for ri in 0..mr {
                        let r = r0 + ri;
                        for ki in k0..kend {
                            let xv = *xp.add(r * k + ki);
                            for jj in j..n {
                                *op.add(r * n + jj) += xv * *wp.add(ki * n + jj);
                            }
                        }
                    }
                }
                r0 += mr;
            }
            k0 = kend;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 via [`available`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_bt_panel(
        out: &mut [f32],
        x: &[f32],
        rows: usize,
        k: usize,
        wt: &[f32],
        n: usize,
        bias: Option<&[f32]>,
    ) {
        debug_assert_eq!(out.len(), rows * n);
        debug_assert_eq!(x.len(), rows * k);
        // j-blocks of wt are transposed into [k, LANES] so the inner
        // loop reads contiguous vectors while each element still
        // accumulates in ascending k (identical to the scalar dot).
        let mut packed = vec![0.0f32; k.max(1) * LANES];
        let pp = packed.as_mut_ptr();
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        let wtp = wt.as_ptr();
        let mut j = 0;
        while j + LANES <= n {
            for ki in 0..k {
                for l in 0..LANES {
                    *pp.add(ki * LANES + l) = *wtp.add((j + l) * k + ki);
                }
            }
            let binit = match bias {
                Some(b) => _mm256_loadu_ps(b.as_ptr().add(j)),
                None => _mm256_setzero_ps(),
            };
            let mut r0 = 0;
            while r0 < rows {
                let mr = (rows - r0).min(MR);
                let mut acc = [binit; MR];
                for ki in 0..k {
                    let wv = _mm256_loadu_ps(pp.add(ki * LANES));
                    for ri in 0..mr {
                        let xv = _mm256_set1_ps(*xp.add((r0 + ri) * k + ki));
                        acc[ri] = _mm256_add_ps(acc[ri], _mm256_mul_ps(xv, wv));
                    }
                }
                for ri in 0..mr {
                    _mm256_storeu_ps(op.add((r0 + ri) * n + j), acc[ri]);
                }
                r0 += mr;
            }
            j += LANES;
        }
        // scalar tail columns: running dot, ascending k
        for jj in j..n {
            for r in 0..rows {
                let mut acc = match bias {
                    Some(b) => b[jj],
                    None => 0.0,
                };
                for ki in 0..k {
                    acc += *xp.add(r * k + ki) * *wtp.add(jj * k + ki);
                }
                *op.add(r * n + jj) = acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// NEON microkernel (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    //! 4-row by 4-column register-tiled microkernel; same ordering
    //! discipline as the AVX2 path (multiply then add — `vmlaq_f32`
    //! would emit fused FMLA and break scalar parity, so it is avoided).

    use core::arch::aarch64::*;

    use super::KC;

    const MR: usize = 4;
    const LANES: usize = 4;

    /// # Safety
    /// NEON is baseline on aarch64; slice invariants as per the scalar
    /// kernel.
    pub unsafe fn gemm_panel(
        out: &mut [f32],
        x: &[f32],
        rows: usize,
        k: usize,
        w: &[f32],
        n: usize,
        bias: Option<&[f32]>,
    ) {
        debug_assert_eq!(out.len(), rows * n);
        debug_assert_eq!(x.len(), rows * k);
        for r in 0..rows {
            let orow = &mut out[r * n..(r + 1) * n];
            match bias {
                Some(b) => orow.copy_from_slice(b),
                None => orow.fill(0.0),
            }
        }
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        let wp = w.as_ptr();
        let mut k0 = 0;
        while k0 < k {
            let kend = (k0 + KC).min(k);
            let mut r0 = 0;
            while r0 < rows {
                let mr = (rows - r0).min(MR);
                let mut j = 0;
                while j + LANES <= n {
                    let mut acc = [vdupq_n_f32(0.0); MR];
                    for ri in 0..mr {
                        acc[ri] = vld1q_f32(op.add((r0 + ri) * n + j));
                    }
                    for ki in k0..kend {
                        let wv = vld1q_f32(wp.add(ki * n + j));
                        for ri in 0..mr {
                            let xv = vdupq_n_f32(*xp.add((r0 + ri) * k + ki));
                            acc[ri] = vaddq_f32(acc[ri], vmulq_f32(xv, wv));
                        }
                    }
                    for ri in 0..mr {
                        vst1q_f32(op.add((r0 + ri) * n + j), acc[ri]);
                    }
                    j += LANES;
                }
                if j < n {
                    for ri in 0..mr {
                        let r = r0 + ri;
                        for ki in k0..kend {
                            let xv = *xp.add(r * k + ki);
                            for jj in j..n {
                                *op.add(r * n + jj) += xv * *wp.add(ki * n + jj);
                            }
                        }
                    }
                }
                r0 += mr;
            }
            k0 = kend;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; slice invariants as per the scalar
    /// kernel.
    pub unsafe fn gemm_bt_panel(
        out: &mut [f32],
        x: &[f32],
        rows: usize,
        k: usize,
        wt: &[f32],
        n: usize,
        bias: Option<&[f32]>,
    ) {
        debug_assert_eq!(out.len(), rows * n);
        debug_assert_eq!(x.len(), rows * k);
        let mut packed = vec![0.0f32; k.max(1) * LANES];
        let pp = packed.as_mut_ptr();
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        let wtp = wt.as_ptr();
        let mut j = 0;
        while j + LANES <= n {
            for ki in 0..k {
                for l in 0..LANES {
                    *pp.add(ki * LANES + l) = *wtp.add((j + l) * k + ki);
                }
            }
            let binit = match bias {
                Some(b) => vld1q_f32(b.as_ptr().add(j)),
                None => vdupq_n_f32(0.0),
            };
            let mut r0 = 0;
            while r0 < rows {
                let mr = (rows - r0).min(MR);
                let mut acc = [binit; MR];
                for ki in 0..k {
                    let wv = vld1q_f32(pp.add(ki * LANES));
                    for ri in 0..mr {
                        let xv = vdupq_n_f32(*xp.add((r0 + ri) * k + ki));
                        acc[ri] = vaddq_f32(acc[ri], vmulq_f32(xv, wv));
                    }
                }
                for ri in 0..mr {
                    vst1q_f32(op.add((r0 + ri) * n + j), acc[ri]);
                }
                r0 += mr;
            }
            j += LANES;
        }
        for jj in j..n {
            for r in 0..rows {
                let mut acc = match bias {
                    Some(b) => b[jj],
                    None => 0.0,
                };
                for ki in 0..k {
                    acc += *xp.add(r * k + ki) * *wtp.add(jj * k + ki);
                }
                *op.add(r * n + jj) = acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel dispatch
// ---------------------------------------------------------------------------

fn gemm_panel(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    simd: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // SAFETY: `simd` is only true after `simd_supported()` verified
        // AVX2 on this CPU; slice invariants checked by the caller.
        unsafe { avx2::gemm_panel(out, x, rows, k, w, n, bias) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::gemm_panel(out, x, rows, k, w, n, bias) };
        return;
    }
    let _ = simd;
    gemm_panel_scalar(out, x, rows, k, w, n, bias)
}

fn gemm_bt_panel(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    wt: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    simd: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // SAFETY: `simd` is only true after `simd_supported()` verified
        // AVX2 on this CPU; slice invariants checked by the caller.
        unsafe { avx2::gemm_bt_panel(out, x, rows, k, wt, n, bias) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if simd {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::gemm_bt_panel(out, x, rows, k, wt, n, bias) };
        return;
    }
    let _ = simd;
    gemm_bt_panel_scalar(out, x, rows, k, wt, n, bias)
}

// ---------------------------------------------------------------------------
// Parallel drivers
// ---------------------------------------------------------------------------

fn check_dims(x: &[f32], m: usize, k: usize, w: &[f32], w_len: usize, n: usize, bias: Option<&[f32]>) {
    assert_eq!(x.len(), m * k, "gemm: x len {} != {m} x {k}", x.len());
    assert_eq!(w.len(), w_len, "gemm: w len {} != expected {w_len}", w.len());
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "gemm: bias len {} != {n}", b.len());
    }
}

/// Split `out` into row panels and run `kernel(panel, x_panel, rows)`
/// on the configured pool (inline when the GEMM is small, serial, or
/// already on a worker thread). Shared with [`crate::tensor::quant`]'s
/// reduced-precision matmuls so every matmul variant parallelises — and
/// degrades under nesting — identically.
pub(crate) fn run_panels<F>(out: &mut [f32], x: &[f32], m: usize, k: usize, n: usize, kernel: F)
where
    F: Fn(&mut [f32], &[f32], usize) + Send + Sync,
{
    let nt = threads();
    if nt <= 1 || m < 2 || m * k * n < MIN_PAR_MACS || on_worker_thread() {
        kernel(out, x, m);
        return;
    }
    let rows_per_panel = (m + nt - 1) / nt;
    // disjoint &mut row panels of `out`, fanned out by index
    let panels: Vec<(usize, &mut [f32])> =
        out.chunks_mut(rows_per_panel * n).enumerate().collect();
    pool_for(nt).scoped_map(panels, |(pi, chunk)| {
        let lo = pi * rows_per_panel;
        let rows = chunk.len() / n;
        kernel(chunk, &x[lo * k..(lo + rows) * k], rows);
    });
}

/// `y[m, n] = x[m, k] @ w[k, n] (+ bias)`, row-major, panel-parallel.
pub fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, bias: Option<&[f32]>) -> Vec<f32> {
    check_dims(x, m, k, w, k * n, n, bias);
    let mut out = vec![0.0f32; m * n];
    // resolved on the calling thread: pool workers always inherit the
    // caller's kernel choice
    let simd = use_simd();
    run_panels(&mut out, x, m, k, n, |o, xs, rows| {
        gemm_panel(o, xs, rows, k, w, n, bias, simd)
    });
    out
}

/// `y[m, n] = x[m, k] @ wt[n, k]^T (+ bias)` — transposed-B variant
/// (attention scores `Q @ K^T` without materialising `K^T`).
pub fn matmul_bt(
    x: &[f32],
    m: usize,
    k: usize,
    wt: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    check_dims(x, m, k, wt, n * k, n, bias);
    let mut out = vec![0.0f32; m * n];
    let simd = use_simd();
    run_panels(&mut out, x, m, k, n, |o, xs, rows| {
        gemm_bt_panel(o, xs, rows, k, wt, n, bias, simd)
    });
    out
}

/// Fan `f` over `items` on the compute pool this thread is configured
/// for (order-preserving). Degrades to an inline serial map when the
/// pool is serial, there is only one item, or the caller is already a
/// pool worker — so callers can nest it under [`matmul`] fan-outs (and
/// vice versa) without deadlock. The reference backend uses this to
/// parallelise attention across `(batch, head)` panels.
pub fn parallel_over<'env, T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'env,
    R: Send + 'env,
    F: Fn(T) -> R + Send + Sync + 'env,
{
    let nt = threads();
    if nt <= 1 || items.len() < 2 || on_worker_thread() {
        return items.into_iter().map(f).collect();
    }
    pool_for(nt).scoped_map(items, f)
}

/// Reference triple loop (unblocked, unconditionally serial). Per
/// output element it accumulates bias-then-ascending-`k` exactly like
/// the panel kernels, so the module tests can require bitwise equality
/// against it.
pub fn matmul_naive(
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    check_dims(x, m, k, w, k * n, n, bias);
    let mut out = vec![0.0f32; m * n];
    for r in 0..m {
        for j in 0..n {
            let mut acc = match bias {
                Some(b) => b[j],
                None => 0.0,
            };
            for ki in 0..k {
                acc += x[r * k + ki] * w[ki * n + j];
            }
            out[r * n + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_vec(n)
    }

    #[test]
    fn matmul_matches_naive_across_shapes_and_threads() {
        for &(m, k, n) in &[
            (1usize, 7usize, 5usize),
            (3, 16, 9),
            (8, 128, 384),
            (64, 128, 512),
            (65, 130, 33), // ragged panels
        ] {
            let x = rand_vec(m * k, 1);
            let w = rand_vec(k * n, 2);
            let b = rand_vec(n, 3);
            let want = matmul_naive(&x, m, k, &w, n, Some(&b));
            for nt in [1usize, 2, 8] {
                let got = with_threads(nt, || matmul(&x, m, k, &w, n, Some(&b)));
                assert_eq!(got.len(), want.len());
                for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - e).abs() <= 1e-5,
                        "({m},{k},{n}) threads={nt} i={i}: {g} vs {e}"
                    );
                }
                // per-element order matches the naive loop exactly
                assert_eq!(got, want, "({m},{k},{n}) threads={nt} not bitwise equal to naive");
            }
        }
    }

    #[test]
    fn matmul_is_bitwise_deterministic_across_thread_counts() {
        let (m, k, n) = (64usize, 128usize, 512usize);
        let x = rand_vec(m * k, 4);
        let w = rand_vec(k * n, 5);
        let t1 = with_threads(1, || matmul(&x, m, k, &w, n, None));
        for nt in [2usize, 3, 8] {
            let tn = with_threads(nt, || matmul(&x, m, k, &w, n, None));
            assert_eq!(t1, tn, "threads={nt} diverged bitwise");
        }
    }

    #[test]
    fn scalar_and_simd_kernels_agree_bitwise() {
        // shapes chosen to exercise every dispatch edge: m smaller than
        // the row tile, k below/above KC, n across the 16/8/scalar
        // column tails, and a bare column vector
        for &(m, k, n) in &[
            (1usize, 3usize, 1usize),
            (1, 64, 16),
            (2, KC - 1, 17),
            (5, KC + 3, 40),
            (7, 33, 23),
            (64, 128, 512),
            (65, 130, 33),
        ] {
            let x = rand_vec(m * k, 11);
            let w = rand_vec(k * n, 12);
            let b = rand_vec(n, 13);
            let scalar = with_kernel(Kernel::Scalar, || matmul(&x, m, k, &w, n, Some(&b)));
            let auto = with_kernel(Kernel::Auto, || matmul(&x, m, k, &w, n, Some(&b)));
            assert_eq!(scalar, auto, "({m},{k},{n}) kernels diverged bitwise");
            let naive = matmul_naive(&x, m, k, &w, n, Some(&b));
            assert_eq!(scalar, naive, "({m},{k},{n}) scalar != naive");
        }
    }

    #[test]
    fn scalar_and_simd_bt_kernels_agree_bitwise() {
        for &(m, k, n) in &[(1usize, 5usize, 3usize), (4, 32, 10), (9, 17, 29), (64, 32, 64)] {
            let x = rand_vec(m * k, 14);
            let wt = rand_vec(n * k, 15);
            let b = rand_vec(n, 16);
            let scalar = with_kernel(Kernel::Scalar, || matmul_bt(&x, m, k, &wt, n, Some(&b)));
            let auto = with_kernel(Kernel::Auto, || matmul_bt(&x, m, k, &wt, n, Some(&b)));
            assert_eq!(scalar, auto, "({m},{k},{n}) bt kernels diverged bitwise");
        }
    }

    #[test]
    fn matmul_bt_matches_materialised_transpose() {
        for &(m, k, n) in &[(4usize, 32usize, 10usize), (64, 32, 64), (33, 17, 29)] {
            let x = rand_vec(m * k, 6);
            let wt = rand_vec(n * k, 7); // [n, k]
            // materialise w = wt^T as [k, n]
            let mut w = vec![0.0f32; k * n];
            for j in 0..n {
                for ki in 0..k {
                    w[ki * n + j] = wt[j * k + ki];
                }
            }
            let want = matmul_naive(&x, m, k, &w, n, None);
            for nt in [1usize, 2, 8] {
                let got = with_threads(nt, || matmul_bt(&x, m, k, &wt, n, None));
                for (g, e) in got.iter().zip(&want) {
                    assert!((g - e).abs() <= 1e-5, "({m},{k},{n}) threads={nt}");
                }
            }
        }
    }

    #[test]
    fn bias_is_applied_per_output_column() {
        let x = vec![0.0f32; 2 * 3];
        let w = vec![0.0f32; 3 * 4];
        let b = vec![1.0f32, 2.0, 3.0, 4.0];
        let out = matmul(&x, 2, 3, &w, 4, Some(&b));
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn with_threads_restores_previous_value() {
        // pin a thread-local scope for the whole test so the global
        // set_threads probe below cannot leak into sibling tests (the
        // CI lanes pin SMOOTHCACHE_THREADS and must keep their setting)
        with_threads(3, || {
            assert_eq!(threads(), 3);
            let inner = with_threads(7, threads);
            assert_eq!(inner, 7);
            assert_eq!(threads(), 3);
            // nested scopes unwind correctly
            with_threads(2, || {
                assert_eq!(threads(), 2);
                with_threads(5, || assert_eq!(threads(), 5));
                assert_eq!(threads(), 2);
            });
            assert_eq!(threads(), 3);
        });
        // set_threads moves the process-wide default; restore it so the
        // rest of the test process keeps the lane's configuration
        let prev = threads();
        set_threads(prev + 1);
        assert_eq!(threads(), prev + 1);
        set_threads(prev);
        assert_eq!(threads(), prev);
    }

    #[test]
    fn with_kernel_restores_previous_value() {
        with_kernel(Kernel::Scalar, || {
            assert_eq!(kernel(), Kernel::Scalar);
            assert_eq!(active_kernel_name(), "scalar");
            with_kernel(Kernel::Auto, || {
                assert_eq!(kernel(), Kernel::Auto);
            });
            assert_eq!(kernel(), Kernel::Scalar);
        });
        // outside any scope the choice defers to the env / auto default
        let ambient = kernel();
        assert!(matches!(ambient, Kernel::Auto | Kernel::Scalar));
        let name = active_kernel_name();
        assert!(
            name == "avx2" || name == "neon" || name == "scalar",
            "unexpected kernel name {name:?}"
        );
    }

    #[test]
    #[should_panic(expected = "gemm: x len")]
    fn dimension_mismatch_panics() {
        matmul(&[0.0; 5], 2, 3, &[0.0; 12], 4, None);
    }
}
