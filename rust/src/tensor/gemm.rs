//! Cache-blocked, threadpool-parallel f32 GEMM (the shared parallel
//! compute substrate).
//!
//! Every reference-backend matmul — token/QKV/output projections, the
//! FFN, the per-head score/value products inside attention — routes
//! through [`matmul`] / [`matmul_bt`]. Work is split into row panels
//! and fanned out over a process-wide [`ThreadPool`] via
//! [`ThreadPool::scoped_map`]; inside a panel the k-dimension is walked
//! in fixed-size blocks so a `KC x n` slab of `w` stays hot in cache
//! across the panel's rows.
//!
//! **Determinism contract:** for a given output element the f32
//! accumulation order is ascending `k`, one term at a time, regardless
//! of thread count, panel boundaries or k-blocking — so results are
//! *bitwise identical* across `--threads` settings and equal to the
//! naive serial triple loop. `tests/parallel_parity.rs` and CI
//! (`SMOOTHCACHE_THREADS=1` vs `4`) lock this in; caching decisions
//! must never depend on parallelism.
//!
//! Thread-count resolution (first match wins):
//! 1. a [`with_threads`] scope on the calling thread,
//! 2. the process-wide count from [`set_threads`] (the `--threads`
//!    CLI knob),
//! 3. the `SMOOTHCACHE_THREADS` environment variable,
//! 4. `available_parallelism()` capped at 8.
//!
//! Calls issued *from* a pool worker (nested parallelism) degrade to
//! inline serial execution instead of deadlocking — see
//! [`on_worker_thread`].

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::threadpool::{on_worker_thread, ThreadPool};

/// k-dimension block: a `KC x n` slab of `w` (`KC x 512` f32 = 256 KiB
/// at the largest builtin width) is reused across every row of a panel
/// before the walk advances.
const KC: usize = 128;

/// Below this many multiply-accumulates a GEMM runs inline: job
/// dispatch over the channel-based pool costs more than it buys.
const MIN_PAR_MACS: usize = 64 * 1024;

// ---------------------------------------------------------------------------
// Thread-count configuration
// ---------------------------------------------------------------------------

/// Process-wide thread count; 0 = not yet resolved.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_threads`]; 0 = none.
    static TL_THREADS: Cell<usize> = const { Cell::new(0) };
}

fn env_or_auto_threads() -> usize {
    std::env::var("SMOOTHCACHE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        })
}

/// Set the process-wide compute thread count (the `--threads` knob).
/// Takes effect for every subsequent GEMM on any thread without an
/// active [`with_threads`] scope.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::SeqCst);
}

/// The thread count the next GEMM on this thread will use.
pub fn threads() -> usize {
    let tl = TL_THREADS.with(|c| c.get());
    if tl > 0 {
        return tl;
    }
    let g = GLOBAL_THREADS.load(Ordering::SeqCst);
    if g > 0 {
        return g;
    }
    let resolved = env_or_auto_threads();
    // benign race: every contender resolves the same value
    let _ = GLOBAL_THREADS.compare_exchange(0, resolved, Ordering::SeqCst, Ordering::SeqCst);
    GLOBAL_THREADS.load(Ordering::SeqCst)
}

/// Run `f` with this thread's GEMM thread count pinned to `n`
/// (restored afterwards, panic-safe). The parity tests sweep thread
/// counts with this without perturbing other test threads.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_THREADS.with(|c| c.set(self.0));
        }
    }
    let prev = TL_THREADS.with(|c| c.replace(n.max(1)));
    let _restore = Restore(prev);
    f()
}

/// Process-wide pool registry, one pool per size. Pools live for the
/// process lifetime; the handful of sizes in play (CLI value, test
/// sweep values) bounds the registry.
fn pool_for(n: usize) -> Arc<ThreadPool> {
    static POOLS: OnceLock<Mutex<HashMap<usize, Arc<ThreadPool>>>> = OnceLock::new();
    let pools = POOLS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = pools.lock().unwrap();
    Arc::clone(guard.entry(n).or_insert_with(|| Arc::new(ThreadPool::new(n))))
}

// ---------------------------------------------------------------------------
// Serial panel kernels
// ---------------------------------------------------------------------------

/// `out[rows, n] = x[rows, k] @ w[k, n] (+ bias)`, k-blocked, axpy form:
/// each output row accumulates terms in ascending `k`, one at a time.
fn gemm_panel(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(x.len(), rows * k);
    for r in 0..rows {
        let orow = &mut out[r * n..(r + 1) * n];
        match bias {
            Some(b) => orow.copy_from_slice(b),
            None => orow.fill(0.0),
        }
    }
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KC).min(k);
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            for ki in k0..kend {
                let xv = xrow[ki];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[ki * n..(ki + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        k0 = kend;
    }
}

/// `out[rows, n] = x[rows, k] @ wt[n, k]^T (+ bias)` — transposed-B
/// variant (each output element is a running dot over ascending `k`).
fn gemm_bt_panel(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    wt: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(x.len(), rows * k);
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let wrow = &wt[j * k..(j + 1) * k];
            let mut acc = match bias {
                Some(b) => b[j],
                None => 0.0,
            };
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += xv * wv;
            }
            *o = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Parallel drivers
// ---------------------------------------------------------------------------

fn check_dims(x: &[f32], m: usize, k: usize, w: &[f32], w_len: usize, n: usize, bias: Option<&[f32]>) {
    assert_eq!(x.len(), m * k, "gemm: x len {} != {m} x {k}", x.len());
    assert_eq!(w.len(), w_len, "gemm: w len {} != expected {w_len}", w.len());
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "gemm: bias len {} != {n}", b.len());
    }
}

fn run_panels(
    out: &mut [f32],
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    kernel: fn(&mut [f32], &[f32], usize, usize, &[f32], usize, Option<&[f32]>),
) {
    let nt = threads();
    if nt <= 1 || m < 2 || m * k * n < MIN_PAR_MACS || on_worker_thread() {
        kernel(out, x, m, k, w, n, bias);
        return;
    }
    let rows_per_panel = (m + nt - 1) / nt;
    // disjoint &mut row panels of `out`, fanned out by index
    let panels: Vec<(usize, &mut [f32])> =
        out.chunks_mut(rows_per_panel * n).enumerate().collect();
    pool_for(nt).scoped_map(panels, |(pi, chunk)| {
        let lo = pi * rows_per_panel;
        let rows = chunk.len() / n;
        kernel(chunk, &x[lo * k..(lo + rows) * k], rows, k, w, n, bias);
    });
}

/// `y[m, n] = x[m, k] @ w[k, n] (+ bias)`, row-major, panel-parallel.
pub fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, bias: Option<&[f32]>) -> Vec<f32> {
    check_dims(x, m, k, w, k * n, n, bias);
    let mut out = vec![0.0f32; m * n];
    run_panels(&mut out, x, m, k, w, n, bias, gemm_panel);
    out
}

/// `y[m, n] = x[m, k] @ wt[n, k]^T (+ bias)` — transposed-B variant
/// (attention scores `Q @ K^T` without materialising `K^T`).
pub fn matmul_bt(
    x: &[f32],
    m: usize,
    k: usize,
    wt: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    check_dims(x, m, k, wt, n * k, n, bias);
    let mut out = vec![0.0f32; m * n];
    run_panels(&mut out, x, m, k, wt, n, bias, gemm_bt_panel);
    out
}

/// Fan `f` over `items` on the compute pool this thread is configured
/// for (order-preserving). Degrades to an inline serial map when the
/// pool is serial, there is only one item, or the caller is already a
/// pool worker — so callers can nest it under [`matmul`] fan-outs (and
/// vice versa) without deadlock. The reference backend uses this to
/// parallelise attention across `(batch, head)` panels.
pub fn parallel_over<'env, T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'env,
    R: Send + 'env,
    F: Fn(T) -> R + Send + Sync + 'env,
{
    let nt = threads();
    if nt <= 1 || items.len() < 2 || on_worker_thread() {
        return items.into_iter().map(f).collect();
    }
    pool_for(nt).scoped_map(items, f)
}

/// Reference triple loop (unblocked, unconditionally serial). The parity
/// suite pins the parallel kernels to this within 1e-5 per element; it
/// is also the fallback the module tests shrink against.
pub fn matmul_naive(
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    check_dims(x, m, k, w, k * n, n, bias);
    let mut out = vec![0.0f32; m * n];
    for r in 0..m {
        for j in 0..n {
            let mut acc = match bias {
                Some(b) => b[j],
                None => 0.0,
            };
            for ki in 0..k {
                acc += x[r * k + ki] * w[ki * n + j];
            }
            out[r * n + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_vec(n)
    }

    #[test]
    fn matmul_matches_naive_across_shapes_and_threads() {
        for &(m, k, n) in &[
            (1usize, 7usize, 5usize),
            (3, 16, 9),
            (8, 128, 384),
            (64, 128, 512),
            (65, 130, 33), // ragged panels
        ] {
            let x = rand_vec(m * k, 1);
            let w = rand_vec(k * n, 2);
            let b = rand_vec(n, 3);
            let want = matmul_naive(&x, m, k, &w, n, Some(&b));
            for nt in [1usize, 2, 8] {
                let got = with_threads(nt, || matmul(&x, m, k, &w, n, Some(&b)));
                assert_eq!(got.len(), want.len());
                for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - e).abs() <= 1e-5,
                        "({m},{k},{n}) threads={nt} i={i}: {g} vs {e}"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_is_bitwise_deterministic_across_thread_counts() {
        let (m, k, n) = (64usize, 128usize, 512usize);
        let x = rand_vec(m * k, 4);
        let w = rand_vec(k * n, 5);
        let t1 = with_threads(1, || matmul(&x, m, k, &w, n, None));
        for nt in [2usize, 3, 8] {
            let tn = with_threads(nt, || matmul(&x, m, k, &w, n, None));
            assert_eq!(t1, tn, "threads={nt} diverged bitwise");
        }
    }

    #[test]
    fn matmul_bt_matches_materialised_transpose() {
        for &(m, k, n) in &[(4usize, 32usize, 10usize), (64, 32, 64), (33, 17, 29)] {
            let x = rand_vec(m * k, 6);
            let wt = rand_vec(n * k, 7); // [n, k]
            // materialise w = wt^T as [k, n]
            let mut w = vec![0.0f32; k * n];
            for j in 0..n {
                for ki in 0..k {
                    w[ki * n + j] = wt[j * k + ki];
                }
            }
            let want = matmul_naive(&x, m, k, &w, n, None);
            for nt in [1usize, 2, 8] {
                let got = with_threads(nt, || matmul_bt(&x, m, k, &wt, n, None));
                for (g, e) in got.iter().zip(&want) {
                    assert!((g - e).abs() <= 1e-5, "({m},{k},{n}) threads={nt}");
                }
            }
        }
    }

    #[test]
    fn bias_is_applied_per_output_column() {
        let x = vec![0.0f32; 2 * 3];
        let w = vec![0.0f32; 3 * 4];
        let b = vec![1.0f32, 2.0, 3.0, 4.0];
        let out = matmul(&x, 2, 3, &w, 4, Some(&b));
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn with_threads_restores_previous_value() {
        // pin a thread-local scope for the whole test so the global
        // set_threads probe below cannot leak into sibling tests (the
        // CI lanes pin SMOOTHCACHE_THREADS and must keep their setting)
        with_threads(3, || {
            assert_eq!(threads(), 3);
            let inner = with_threads(7, threads);
            assert_eq!(inner, 7);
            assert_eq!(threads(), 3);
            // nested scopes unwind correctly
            with_threads(2, || {
                assert_eq!(threads(), 2);
                with_threads(5, || assert_eq!(threads(), 5));
                assert_eq!(threads(), 2);
            });
            assert_eq!(threads(), 3);
        });
        // set_threads moves the process-wide default; restore it so the
        // rest of the test process keeps the lane's configuration
        let prev = threads();
        set_threads(prev + 1);
        assert_eq!(threads(), prev + 1);
        set_threads(prev);
        assert_eq!(threads(), prev);
    }

    #[test]
    #[should_panic(expected = "gemm: x len")]
    fn dimension_mismatch_panics() {
        matmul(&[0.0; 5], 2, 3, &[0.0; 12], 4, None);
    }
}
