//! Reduced-precision compute ladder: weight-only quantization for the
//! reference backend's matmuls.
//!
//! SmoothCache's win is skipping branch evaluations; this module makes
//! the branches it *does* evaluate cheaper to store and stream. A
//! [`ComputeMode`] selects how the B operand (the weight matrix) of a
//! matmul is stored — IEEE binary16, bfloat16, or int8 with one f32
//! scale per output column — while activations and accumulation stay
//! f32 throughout, so the determinism contract of [`super::gemm`]
//! carries over unchanged: per output element the accumulation order is
//! ascending `k`, one term at a time, bitwise invariant to thread
//! count. Reduced-precision outputs are *expected* to differ from the
//! f32 reference; `quality::precision_gate` bounds how much (see
//! docs/adr/006).
//!
//! The mode is ambient per thread (default [`ComputeMode::F32`]) and
//! scoped with [`with_compute`]; the pipeline pins it around each
//! generation step from `GenConfig::compute`, which in turn arrives
//! from the request's `compute:` knob (CLI `--compute`, wire field
//! `compute`). Conversions are hand-rolled bit twiddling — no half-
//! precision crate — per the zero-dependency rule (docs/adr/001).

use std::cell::Cell;

use super::gemm;
use crate::util::error::Result;

// ---------------------------------------------------------------------------
// ComputeMode
// ---------------------------------------------------------------------------

/// Numeric mode for reference-backend weight matmuls. `F32` is the
/// bitwise-deterministic reference; the reduced modes trade accuracy
/// for storage/bandwidth and are gated against the reference by
/// `quality::precision_gate`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub enum ComputeMode {
    /// Full-precision f32 weights (the default; the parity reference).
    #[default]
    F32,
    /// IEEE binary16 weight storage, f32 accumulation.
    F16,
    /// bfloat16 weight storage, f32 accumulation.
    Bf16,
    /// int8 weights with one f32 scale per output column, f32
    /// accumulation.
    Int8,
}

impl ComputeMode {
    pub const ALL: [ComputeMode; 4] =
        [ComputeMode::F32, ComputeMode::F16, ComputeMode::Bf16, ComputeMode::Int8];

    /// The modes that actually re-encode weights.
    pub const REDUCED: [ComputeMode; 3] = [ComputeMode::F16, ComputeMode::Bf16, ComputeMode::Int8];

    pub fn parse(s: &str) -> Result<ComputeMode> {
        match s {
            "f32" => Ok(ComputeMode::F32),
            "f16" => Ok(ComputeMode::F16),
            "bf16" => Ok(ComputeMode::Bf16),
            "int8" => Ok(ComputeMode::Int8),
            other => Err(crate::err!(
                "unknown compute mode {other:?} (expected f32 | f16 | bf16 | int8)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ComputeMode::F32 => "f32",
            ComputeMode::F16 => "f16",
            ComputeMode::Bf16 => "bf16",
            ComputeMode::Int8 => "int8",
        }
    }

    pub fn is_reduced(self) -> bool {
        self != ComputeMode::F32
    }
}

thread_local! {
    /// Ambient compute mode installed by [`with_compute`].
    static TL_COMPUTE: Cell<ComputeMode> = const { Cell::new(ComputeMode::F32) };
}

/// The compute mode ambient on this thread (default `F32`). Resolved on
/// the thread driving a generation step; pool workers never consult it
/// (kernels receive already-quantized operands).
pub fn compute_mode() -> ComputeMode {
    TL_COMPUTE.with(|c| c.get())
}

/// Run `f` with this thread's compute mode pinned (restored afterwards,
/// panic-safe) — same scoping idiom as [`gemm::with_threads`].
pub fn with_compute<R>(mode: ComputeMode, f: impl FnOnce() -> R) -> R {
    struct Restore(ComputeMode);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_COMPUTE.with(|c| c.set(self.0));
        }
    }
    let prev = TL_COMPUTE.with(|c| c.replace(mode));
    let _restore = Restore(prev);
    f()
}

// ---------------------------------------------------------------------------
// Half-precision bit conversions (round-to-nearest-even)
// ---------------------------------------------------------------------------

/// f32 -> IEEE binary16 bits, round-to-nearest-even; overflow saturates
/// to infinity, NaN keeps its sign and a quiet payload.
pub fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = (bits >> 16) & 0x8000;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN: preserve the top mantissa bits, force a quiet bit
        // on NaN so the payload survives the narrowing
        let nan = if man != 0 { (man >> 13) | 0x0200 } else { 0 };
        return (sign | 0x7c00 | nan) as u16;
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return (sign | 0x7c00) as u16; // overflow -> inf
    }
    if e <= 0 {
        // subnormal half (or underflow to signed zero)
        if e < -10 {
            return sign as u16;
        }
        let man = man | 0x0080_0000; // make the leading 1 explicit
        let shift = (14 - e) as u32; // 14..=24
        let base = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let rounded = if rem > half || (rem == half && base & 1 == 1) { base + 1 } else { base };
        return (sign | rounded) as u16;
    }
    // normal: narrow the mantissa 23 -> 10 bits; a mantissa carry rolls
    // into the exponent (and, at the top, correctly to infinity)
    let base = sign | ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && base & 1 == 1) { base + 1 } else { base };
    rounded as u16
}

/// IEEE binary16 bits -> f32 (exact; every f16 value is representable).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // subnormal: renormalise into an f32 normal
            let mut e: i32 = 127 - 15 + 1;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> bfloat16 bits (top 16 bits, round-to-nearest-even).
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // keep sign + a payload bit that survives truncation
        return ((bits >> 16) as u16) | 0x0040;
    }
    let base = bits >> 16;
    let rem = bits & 0xffff;
    let rounded = if rem > 0x8000 || (rem == 0x8000 && base & 1 == 1) { base + 1 } else { base };
    rounded as u16
}

/// bfloat16 bits -> f32 (exact).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------------
// QuantMat
// ---------------------------------------------------------------------------

/// A weight matrix re-encoded for a reduced [`ComputeMode`], `[k, n]`
/// row-major like [`gemm::matmul`]'s B operand. Built once per weight
/// tensor (cached by `model::weights::WeightStore::get_quant`) and
/// shared by every subsequent matmul in that mode.
#[derive(Clone, Debug)]
pub enum QuantMat {
    /// IEEE binary16 storage.
    F16 { data: Vec<u16>, k: usize, n: usize },
    /// bfloat16 storage.
    Bf16 { data: Vec<u16>, k: usize, n: usize },
    /// int8 storage with one f32 scale per output column — per-row
    /// scales of the `[n, k]` output-major view of the weight.
    Int8 { data: Vec<i8>, scales: Vec<f32>, k: usize, n: usize },
}

impl QuantMat {
    /// Re-encode `w` (`[k, n]` row-major). Returns `None` for
    /// [`ComputeMode::F32`], which has no re-encoded form.
    pub fn quantize(w: &[f32], k: usize, n: usize, mode: ComputeMode) -> Option<QuantMat> {
        assert_eq!(w.len(), k * n, "quantize: w len {} != {k} x {n}", w.len());
        match mode {
            ComputeMode::F32 => None,
            ComputeMode::F16 => Some(QuantMat::F16 {
                data: w.iter().map(|&v| f32_to_f16(v)).collect(),
                k,
                n,
            }),
            ComputeMode::Bf16 => Some(QuantMat::Bf16 {
                data: w.iter().map(|&v| f32_to_bf16(v)).collect(),
                k,
                n,
            }),
            ComputeMode::Int8 => {
                let mut scales = vec![0.0f32; n];
                for (j, s) in scales.iter_mut().enumerate() {
                    let mut absmax = 0.0f32;
                    for ki in 0..k {
                        absmax = absmax.max(w[ki * n + j].abs());
                    }
                    // an all-zero column quantizes to zeros under any
                    // scale; 1.0 keeps the dequant finite
                    *s = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                }
                let mut data = vec![0i8; k * n];
                for ki in 0..k {
                    for j in 0..n {
                        let q = (w[ki * n + j] / scales[j]).round();
                        data[ki * n + j] = q.clamp(-127.0, 127.0) as i8;
                    }
                }
                Some(QuantMat::Int8 { data, scales, k, n })
            }
        }
    }

    pub fn mode(&self) -> ComputeMode {
        match self {
            QuantMat::F16 { .. } => ComputeMode::F16,
            QuantMat::Bf16 { .. } => ComputeMode::Bf16,
            QuantMat::Int8 { .. } => ComputeMode::Int8,
        }
    }

    pub fn k(&self) -> usize {
        match self {
            QuantMat::F16 { k, .. } | QuantMat::Bf16 { k, .. } | QuantMat::Int8 { k, .. } => *k,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            QuantMat::F16 { n, .. } | QuantMat::Bf16 { n, .. } | QuantMat::Int8 { n, .. } => *n,
        }
    }

    /// Stored payload bytes (for bench metadata / memory accounting).
    pub fn bytes(&self) -> usize {
        match self {
            QuantMat::F16 { data, .. } | QuantMat::Bf16 { data, .. } => data.len() * 2,
            QuantMat::Int8 { data, scales, .. } => data.len() + scales.len() * 4,
        }
    }

    /// Expand back to f32 `[k, n]`. For `F16`/`Bf16` this is exactly
    /// the matrix [`matmul_q`] accumulates (decoding is exact); for
    /// `Int8` it folds the column scale into each element, which
    /// [`matmul_q`] instead applies once per output after accumulating
    /// `x . q` — numerically close but not bitwise identical.
    pub fn dequantize(&self) -> Vec<f32> {
        match self {
            QuantMat::F16 { data, .. } => data.iter().map(|&h| f16_to_f32(h)).collect(),
            QuantMat::Bf16 { data, .. } => data.iter().map(|&h| bf16_to_f32(h)).collect(),
            QuantMat::Int8 { data, scales, k, n } => {
                let mut out = vec![0.0f32; k * n];
                for ki in 0..*k {
                    for j in 0..*n {
                        out[ki * n + j] = data[ki * n + j] as f32 * scales[j];
                    }
                }
                out
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized matmul
// ---------------------------------------------------------------------------

/// `y[m, n] = x[m, k] @ deq(w)[k, n] (+ bias)` with f32 accumulation.
///
/// Per output element the accumulation order is ascending `k`, one term
/// at a time — the same determinism contract as [`gemm::matmul`], so
/// results are bitwise invariant to thread count. Half-precision rows
/// are decoded once per k-block into an f32 slab shared by the panel's
/// rows (decode cost is `O(k*n)` per panel, not `O(m*k*n)`); int8
/// accumulates `x . q` in f32 and applies the per-column scale, then
/// bias, once per output: `y = (sum x*q) * s + b`.
pub fn matmul_q(x: &[f32], m: usize, k: usize, w: &QuantMat, bias: Option<&[f32]>) -> Vec<f32> {
    assert_eq!(w.k(), k, "matmul_q: w rows {} != {k}", w.k());
    let n = w.n();
    assert_eq!(x.len(), m * k, "matmul_q: x len {} != {m} x {k}", x.len());
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "matmul_q: bias len {} != {n}", b.len());
    }
    let mut out = vec![0.0f32; m * n];
    match w {
        QuantMat::F16 { data, .. } => {
            gemm::run_panels(&mut out, x, m, k, n, |o, xs, rows| {
                qgemm_panel(o, xs, rows, k, n, bias, |ki, dst| {
                    for (d, &h) in dst.iter_mut().zip(&data[ki * n..(ki + 1) * n]) {
                        *d = f16_to_f32(h);
                    }
                });
            });
        }
        QuantMat::Bf16 { data, .. } => {
            gemm::run_panels(&mut out, x, m, k, n, |o, xs, rows| {
                qgemm_panel(o, xs, rows, k, n, bias, |ki, dst| {
                    for (d, &h) in dst.iter_mut().zip(&data[ki * n..(ki + 1) * n]) {
                        *d = bf16_to_f32(h);
                    }
                });
            });
        }
        QuantMat::Int8 { data, scales, .. } => {
            gemm::run_panels(&mut out, x, m, k, n, |o, xs, rows| {
                qgemm_panel(o, xs, rows, k, n, None, |ki, dst| {
                    for (d, &q) in dst.iter_mut().zip(&data[ki * n..(ki + 1) * n]) {
                        *d = q as f32;
                    }
                });
                for r in 0..rows {
                    let orow = &mut o[r * n..(r + 1) * n];
                    for (j, v) in orow.iter_mut().enumerate() {
                        let b = match bias {
                            Some(b) => b[j],
                            None => 0.0,
                        };
                        *v = *v * scales[j] + b;
                    }
                }
            });
        }
    }
    out
}

/// Shared k-blocked axpy over a decoded f32 slab. `decode_row(ki, dst)`
/// fills `dst` (length `n`) with row `ki` of the weight as f32.
fn qgemm_panel(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    decode_row: impl Fn(usize, &mut [f32]),
) {
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(x.len(), rows * k);
    for r in 0..rows {
        let orow = &mut out[r * n..(r + 1) * n];
        match bias {
            Some(b) => orow.copy_from_slice(b),
            None => orow.fill(0.0),
        }
    }
    if k == 0 || n == 0 {
        return;
    }
    let kc = gemm::KC.min(k);
    let mut slab = vec![0.0f32; kc * n];
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + kc).min(k);
        for ki in k0..kend {
            decode_row(ki, &mut slab[(ki - k0) * n..(ki - k0 + 1) * n]);
        }
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            for ki in k0..kend {
                let xv = xrow[ki];
                let srow = &slab[(ki - k0) * n..(ki - k0 + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(srow) {
                    *o += xv * wv;
                }
            }
        }
        k0 = kend;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_vec(n)
    }

    #[test]
    fn f16_known_values_round_trip() {
        for &(v, bits) in &[
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (0.5, 0x3800),
            (-2.0, 0xc000),
            (65504.0, 0x7bff), // max finite f16
        ] {
            assert_eq!(f32_to_f16(v), bits, "encode {v}");
            assert_eq!(f16_to_f32(bits), v, "decode {bits:#06x}");
        }
        // min normal and min subnormal f16, as exact powers of two
        assert_eq!(f32_to_f16(2.0f32.powi(-14)), 0x0400);
        assert_eq!(f16_to_f32(0x0400), 2.0f32.powi(-14));
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16(65520.0), 0x7c00, "first value past max rounds to inf");
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // negative zero survives
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f16_to_f32(0x8000).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // exactly between 1.0 (0x3c00) and the next f16 up (0x3c01):
        // ties go to the even mantissa
        let tie_low = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(tie_low), 0x3c00);
        // between 0x3c01 and 0x3c02: rounds up to the even one
        let tie_high = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(tie_high), 0x3c02);
        // half the min subnormal is a tie against zero -> even -> zero
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        // anything above that half becomes the min subnormal
        assert_eq!(f32_to_f16(1.5 * 2.0f32.powi(-25)), 0x0001);
    }

    #[test]
    fn f16_decode_encode_is_identity_for_all_finite_bits() {
        for h in 0u16..0x7c00 {
            for sign in [0u16, 0x8000] {
                let bits = h | sign;
                assert_eq!(f32_to_f16(f16_to_f32(bits)), bits, "{bits:#06x}");
            }
        }
    }

    #[test]
    fn bf16_known_values_and_ties() {
        assert_eq!(f32_to_bf16(1.0), 0x3f80);
        assert_eq!(bf16_to_f32(0x3f80), 1.0);
        assert_eq!(f32_to_bf16(-2.0), 0xc000);
        // tie with even base stays; tie with odd base rounds up
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f80_8000)), 0x3f80);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f81_8000)), 0x3f82);
        // rounding past max finite saturates through to inf
        assert_eq!(f32_to_bf16(f32::from_bits(0x7f7f_ffff)), 0x7f80);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // round trip is identity for values already on the bf16 grid
        for &v in &[0.0f32, -0.0, 3.5, -0.0625, 1024.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v);
        }
    }

    #[test]
    fn int8_quantize_uses_per_column_absmax() {
        // column 0 spans [-4, 2] -> scale 4/127; column 1 is all zeros
        let w = vec![2.0f32, 0.0, -4.0, 0.0, 1.0, 0.0]; // [3, 2]
        let q = QuantMat::quantize(&w, 3, 2, ComputeMode::Int8).unwrap();
        match &q {
            QuantMat::Int8 { data, scales, .. } => {
                assert!((scales[0] - 4.0 / 127.0).abs() < 1e-7);
                assert_eq!(scales[1], 1.0);
                assert_eq!(data[0], 64); // round(2 / (4/127)) = round(63.5) = 64
                assert_eq!(data[2], -127);
                assert_eq!(data[1], 0);
            }
            _ => unreachable!(),
        }
        let deq = q.dequantize();
        assert!((deq[2] - -4.0).abs() < 1e-6, "absmax element is exact");
        assert_eq!(deq[1], 0.0);
    }

    #[test]
    fn quantize_returns_none_for_f32() {
        assert!(QuantMat::quantize(&[1.0, 2.0], 1, 2, ComputeMode::F32).is_none());
    }

    #[test]
    fn matmul_q_half_matches_f32_matmul_of_dequantized_weights() {
        // decoding f16/bf16 is exact, and matmul_q accumulates in the
        // same order as gemm::matmul -> bitwise equality
        for mode in [ComputeMode::F16, ComputeMode::Bf16] {
            for &(m, k, n) in &[(1usize, 7usize, 5usize), (4, 130, 33), (9, 64, 17)] {
                let x = rand_vec(m * k, 21);
                let w = rand_vec(k * n, 22);
                let b = rand_vec(n, 23);
                let q = QuantMat::quantize(&w, k, n, mode).unwrap();
                let got = matmul_q(&x, m, k, &q, Some(&b));
                let want = gemm::matmul(&x, m, k, &q.dequantize(), n, Some(&b));
                assert_eq!(got, want, "{mode:?} ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_q_int8_matches_reference_factoring() {
        let (m, k, n) = (3usize, 40usize, 9usize);
        let x = rand_vec(m * k, 24);
        let w = rand_vec(k * n, 25);
        let b = rand_vec(n, 26);
        let q = QuantMat::quantize(&w, k, n, ComputeMode::Int8).unwrap();
        let got = matmul_q(&x, m, k, &q, Some(&b));
        let (data, scales) = match &q {
            QuantMat::Int8 { data, scales, .. } => (data, scales),
            _ => unreachable!(),
        };
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for ki in 0..k {
                    acc += x[r * k + ki] * data[ki * n + j] as f32;
                }
                let want = acc * scales[j] + b[j];
                assert_eq!(got[r * n + j], want, "({r},{j})");
            }
        }
        // and the factored result approximates the f32 product
        let f32_out = gemm::matmul(&x, m, k, &w, n, Some(&b));
        for (g, e) in got.iter().zip(&f32_out) {
            assert!((g - e).abs() < 0.05, "int8 drifted too far: {g} vs {e}");
        }
    }

    #[test]
    fn matmul_q_is_bitwise_invariant_to_thread_count() {
        let (m, k, n) = (64usize, 128usize, 96usize);
        let x = rand_vec(m * k, 27);
        let w = rand_vec(k * n, 28);
        for mode in ComputeMode::REDUCED {
            let q = QuantMat::quantize(&w, k, n, mode).unwrap();
            let t1 = gemm::with_threads(1, || matmul_q(&x, m, k, &q, None));
            for nt in [2usize, 8] {
                let tn = gemm::with_threads(nt, || matmul_q(&x, m, k, &q, None));
                assert_eq!(t1, tn, "{mode:?} threads={nt} diverged bitwise");
            }
        }
    }

    #[test]
    fn with_compute_restores_previous_mode() {
        assert_eq!(compute_mode(), ComputeMode::F32);
        with_compute(ComputeMode::Int8, || {
            assert_eq!(compute_mode(), ComputeMode::Int8);
            with_compute(ComputeMode::F16, || {
                assert_eq!(compute_mode(), ComputeMode::F16);
            });
            assert_eq!(compute_mode(), ComputeMode::Int8);
        });
        assert_eq!(compute_mode(), ComputeMode::F32);
    }

    #[test]
    fn compute_mode_parses_and_names_round_trip() {
        for mode in ComputeMode::ALL {
            assert_eq!(ComputeMode::parse(mode.name()).unwrap(), mode);
        }
        let err = ComputeMode::parse("fp8").unwrap_err();
        assert!(err.to_string().contains("unknown compute mode"), "{err}");
    }
}
