//! Host-side dense f32 tensor (substrate).
//!
//! The L3 hot path moves activations between backend executions, solvers
//! and the layer cache as host tensors; this module provides the small
//! op set those layers need. Heavy matmuls live in the [`gemm`]
//! submodule — a cache-blocked, threadpool-parallel f32 GEMM with a
//! runtime-dispatched SIMD microkernel (AVX2/NEON, bitwise-identical to
//! the scalar reference) that the reference backend routes every
//! projection, FFN and attention product through (no BLAS offline; PJRT
//! owns the math on that backend). The [`quant`] submodule adds the
//! opt-in reduced-precision ladder: f16/bf16/int8 weight storage with
//! f32 accumulation, selected per request via the `compute:` knob.

pub mod gemm;
pub mod quant;

pub use quant::ComputeMode;

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
            "shape {shape:?} vs data len {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn randn(shape: Vec<usize>, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: rng.normal_vec(n) }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Leading (batch) dimension.
    pub fn dim0(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Elements per leading-dim slice.
    pub fn stride0(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    // ---- elementwise -------------------------------------------------------

    pub fn map(&self, mut f: impl FnMut(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip(&self, other: &Tensor, mut f: impl FnMut(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// self += other * s (in place; the engine's residual-add hot path).
    pub fn axpy(&mut self, other: &Tensor, s: f32) {
        assert_eq!(self.shape, other.shape);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b * s;
        }
    }

    pub fn add_inplace(&mut self, other: &Tensor) {
        self.axpy(other, 1.0);
    }

    // ---- reductions --------------------------------------------------------

    pub fn l1(&self) -> f64 {
        self.data.iter().map(|&x| x.abs() as f64).sum()
    }

    pub fn l2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
        }
    }

    pub fn var(&self) -> f64 {
        let m = self.mean();
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / self.data.len() as f64
        }
    }

    /// Paper Eq. 4 numerator/denominator: ||a - b||1 / ||a||1.
    pub fn rel_l1_error(&self, other: &Tensor) -> f64 {
        let denom = self.l1().max(1e-12);
        self.sub(other).l1() / denom
    }

    // ---- batch manipulation (dim 0) ----------------------------------------

    /// Copy of samples `[lo, hi)` along dim 0.
    pub fn batch_slice(&self, lo: usize, hi: usize) -> Tensor {
        let s = self.stride0();
        assert!(hi <= self.dim0() && lo <= hi);
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { shape, data: self.data[lo * s..hi * s].to_vec() }
    }

    /// One sample along dim 0 (keeps the leading dim as 1).
    pub fn sample(&self, i: usize) -> Tensor {
        self.batch_slice(i, i + 1)
    }

    /// Concatenate along dim 0. All inputs must agree on trailing dims.
    pub fn cat0(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let mut total = 0;
        for p in parts {
            assert_eq!(&p.shape[1..], tail, "trailing dims differ");
            total += p.dim0();
        }
        let mut shape = parts[0].shape.clone();
        shape[0] = total;
        let mut data = Vec::with_capacity(total * parts[0].stride0());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }

    /// Pad dim 0 up to `n` by repeating the last sample (batcher padding).
    pub fn pad0_to(&self, n: usize) -> Tensor {
        let b = self.dim0();
        assert!(n >= b && b > 0);
        if n == b {
            return self.clone();
        }
        let s = self.stride0();
        let mut data = self.data.clone();
        let last = self.data[(b - 1) * s..b * s].to_vec();
        for _ in b..n {
            data.extend_from_slice(&last);
        }
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor { shape, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.stride0(), 3);
        let r = t.reshape(vec![3, 2]);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3], vec![10., 20., 30.]);
        assert_eq!(a.add(&b).data, vec![11., 22., 33.]);
        assert_eq!(b.sub(&a).data, vec![9., 18., 27.]);
        assert_eq!(a.mul(&b).data, vec![10., 40., 90.]);
        assert_eq!(a.scale(2.0).data, vec![2., 4., 6.]);
    }

    #[test]
    fn axpy_inplace() {
        let mut a = Tensor::new(vec![2], vec![1., 1.]);
        let b = Tensor::new(vec![2], vec![2., 4.]);
        a.axpy(&b, 0.5);
        assert_eq!(a.data, vec![2., 3.]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(vec![4], vec![1., -2., 3., -4.]);
        assert_eq!(t.l1(), 10.0);
        assert!((t.l2() - 30f64.sqrt()).abs() < 1e-9);
        assert_eq!(t.max_abs(), 4.0);
        assert_eq!(t.mean(), -0.5);
    }

    #[test]
    fn rel_l1_error_of_self_is_zero() {
        let t = Tensor::new(vec![3], vec![1., 2., 3.]);
        assert_eq!(t.rel_l1_error(&t), 0.0);
        let o = Tensor::new(vec![3], vec![2., 2., 3.]);
        assert!((t.rel_l1_error(&o) - 1.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn batch_slice_and_cat() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let s = t.batch_slice(1, 3);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
        let c = Tensor::cat0(&[&t.sample(0), &s]);
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.data, t.data);
    }

    #[test]
    fn pad0_repeats_last() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let p = t.pad0_to(4);
        assert_eq!(p.shape, vec![4, 2]);
        assert_eq!(&p.data[4..], &[3., 4., 3., 4.]);
    }

    #[test]
    fn randn_is_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(Tensor::randn(vec![10], &mut r1), Tensor::randn(vec![10], &mut r2));
    }
}
