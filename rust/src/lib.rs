//! SmoothCache — a Rust + JAX + Pallas reproduction of
//! *SmoothCache: A Universal Inference Acceleration Technique for
//! Diffusion Transformers* (2024).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`).
//! * **L2** — JAX DiT model families, AOT-lowered to HLO text per
//!   (family, branch, batch) — `python/compile/model.py` + `aot.py`.
//! * **L3** — this crate: the serving coordinator. It executes the DiT
//!   through a pluggable [`runtime::Backend`] (the pure-Rust
//!   [`runtime::reference`] backend by default; PJRT-loaded AOT
//!   artifacts behind the `pjrt` cargo feature), composes forward
//!   passes at the caching granularity ([`model`]), runs the diffusion
//!   solvers ([`solvers`]), and implements the paper's contribution —
//!   the calibration-driven caching schedule ([`cache`]) — under a
//!   dynamic batching serving loop ([`coordinator`], [`server`]; wire
//!   format in docs/protocol.md).
//!
//! Python never runs on the request path, and the default build needs
//! no artifacts, network, or external crates at all
//! (docs/adr/001-zero-dependency-default-build.md).

pub mod cache;
pub mod coordinator;
pub mod experiments;
pub mod linalg;
pub mod macs;
pub mod model;
pub mod obs;
pub mod pipeline;
pub mod quality;
pub mod runtime;
pub mod server;
pub mod solvers;
pub mod tensor;
pub mod util;
pub mod workload;

/// Locate the artifacts directory: `$SMOOTHCACHE_ARTIFACTS` or
/// `<repo>/artifacts` (relative to the crate root at build time).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SMOOTHCACHE_ARTIFACTS") {
        return p.into();
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
