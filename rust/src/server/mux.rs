//! Protocol v2 multiplexed connection handler (docs/protocol.md
//! §Protocol v2, ADR-008).
//!
//! One TCP connection carries many concurrent generations. A single
//! reader loop decodes [`super::frame`] frames and dispatches them:
//! control commands are answered inline, generation requests are
//! admitted against the per-connection credit window
//! ([`super::ServerOpts::conn_inflight`]) and driven by one worker
//! thread each through the existing [`Coordinator::submit_opts`]
//! ticket machinery. All egress — responses, step events, credits,
//! pongs, protocol errors — goes through a `Mutex`-ordered writer, one
//! `write_all` per frame, so interleaved streams never corrupt.
//!
//! Flow control: every `request` frame costs the client one credit;
//! the server returns exactly one `credit` frame per answered request
//! (at generation completion, or immediately for control replies and
//! rejections). A request arriving with the window full — more than
//! `conn_inflight` generations already in flight on this connection —
//! is answered with a typed `overloaded:` error response instead of
//! growing the queue unboundedly (the coordinator's own admission
//! control, ADR-002, still applies behind the window).
//!
//! Malformed frames (oversized length, unknown type) and protocol
//! violations (duplicate in-flight id, client-sent server frame types)
//! are answered with `error` frames and never tear down the
//! connection's other streams. Keepalive: an idle connection (nothing
//! in flight, no inbound frames for [`super::ServerOpts::idle_timeout`])
//! is pinged; an unanswered ping reaps the connection.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, Metrics, Progress, SubmitOpts};
use crate::obs::{Outcome, TraceHandle};
use crate::util::error::Result;
use crate::util::json::{parse, scan_str, Json};

use super::frame::{Decoded, Frame, FrameError, FrameReader, FrameType, VERSION};
use super::{fail, handle_control, parse_request, render_result_json, step_event, ServerOpts};

/// Read-timeout tick for the v2 reader loop: bounds stop-flag latency,
/// keepalive granularity and teardown time.
const POLL_MS: u64 = 50;
/// Worker reply-poll interval (matches the v1 `GEN_POLL_MS` cadence).
const REPLY_POLL_MS: u64 = 10;

/// One in-flight generation on this connection, keyed by the
/// client-chosen request id.
struct Flight {
    /// Coordinator-assigned id once the worker has submitted; `None`
    /// in the submit window (a cancel arriving then sets the flag).
    coord_id: Option<u64>,
    /// Cancel requested before the coordinator id was known.
    cancel_requested: bool,
}

/// State shared between the reader loop and per-request workers.
struct ConnShared {
    coord: Arc<Coordinator>,
    /// Mutex-ordered egress: exactly one frame per lock hold.
    writer: Mutex<TcpStream>,
    /// In-flight generations by wire id (its size *is* the window).
    inflight: Mutex<HashMap<u64, Flight>>,
    /// Set on socket error / teardown; workers drop their work.
    dead: AtomicBool,
}

impl ConnShared {
    /// Serialize one frame onto the connection. Returns `false` (and
    /// marks the connection dead) if the peer is gone.
    fn send(&self, f: &Frame) -> bool {
        if self.dead.load(Ordering::SeqCst) {
            return false;
        }
        let mut w = self.writer.lock().unwrap();
        let ok = f.write_to(&mut *w).and_then(|_| w.flush()).is_ok();
        if !ok {
            self.dead.store(true, Ordering::SeqCst);
        }
        ok
    }

    /// An `error` frame: protocol-level notice that never resolves a
    /// request handle (terminal outcomes are `response` frames).
    fn send_error(&self, id: u64, msg: &str) -> bool {
        let payload = Json::obj().set("ok", false).set("error", msg);
        self.send(&Frame::json(FrameType::Error, id, &payload))
    }

    /// Terminal `response` frame followed by the credit replenishing
    /// the request's window slot.
    fn send_response(&self, id: u64, body: &str) -> bool {
        let ok = self.send(&Frame::new(FrameType::Response, id, body.as_bytes().to_vec()));
        ok && self.send(&Frame::empty(FrameType::Credit, id))
    }
}

/// Drive one v2 connection to completion. Called by the server's
/// dispatcher after the `SMC2` magic has been consumed.
pub fn handle_conn_v2(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    stop: &AtomicBool,
    opts: ServerOpts,
) -> Result<()> {
    Metrics::inc(&coord.metrics().v2_connections);
    stream.set_read_timeout(Some(Duration::from_millis(POLL_MS)))?;
    let mut sock = stream.try_clone()?;
    let shared = Arc::new(ConnShared {
        coord,
        writer: Mutex::new(stream),
        inflight: Mutex::new(HashMap::new()),
        dead: AtomicBool::new(false),
    });
    let mut reader = FrameReader::new(opts.max_frame);
    let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut buf = [0u8; 8192];
    let mut last_inbound = Instant::now();
    let mut pinged_at: Option<Instant> = None;
    let mut hello_done = false;

    'conn: loop {
        if stop.load(Ordering::SeqCst) || shared.dead.load(Ordering::SeqCst) {
            break;
        }
        match sock.read(&mut buf) {
            Ok(0) => {
                if reader.is_mid_frame() {
                    // truncated mid-frame: best-effort typed notice for
                    // a peer that half-closed its write side
                    shared.send_error(0, &FrameError::Truncated.to_string());
                }
                break;
            }
            Ok(n) => {
                reader.extend(&buf[..n]);
                last_inbound = Instant::now();
                pinged_at = None;
                loop {
                    match reader.decode() {
                        Decoded::Incomplete => break,
                        Decoded::Malformed(e) => {
                            // the decoder skips the bad frame's extent;
                            // other streams on this connection survive
                            shared.send_error(0, &e.to_string());
                        }
                        Decoded::Frame(f) => {
                            if !hello_done {
                                if !handshake(&shared, &f, opts) {
                                    break 'conn;
                                }
                                hello_done = true;
                                continue;
                            }
                            dispatch(&shared, f, stop, opts, &mut workers);
                        }
                    }
                }
                workers.retain(|h| !h.is_finished());
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // idle tick: keepalive / reaper bookkeeping
                if opts.idle_timeout > Duration::ZERO
                    && shared.inflight.lock().unwrap().is_empty()
                {
                    let grace = opts.idle_timeout.min(Duration::from_secs(5));
                    match pinged_at {
                        None if last_inbound.elapsed() >= opts.idle_timeout => {
                            shared.send(&Frame::empty(FrameType::Ping, 0));
                            pinged_at = Some(Instant::now());
                        }
                        Some(t) if t.elapsed() >= grace => break, // reap
                        _ => {}
                    }
                }
            }
            Err(_) => break,
        }
    }

    // teardown: nobody is left to read results — stop in-flight work at
    // the next solver step and let workers observe the dead flag
    shared.dead.store(true, Ordering::SeqCst);
    {
        let inflight = shared.inflight.lock().unwrap();
        for flight in inflight.values() {
            if let Some(cid) = flight.coord_id {
                shared.coord.cancel(cid);
            }
        }
    }
    for h in workers {
        let _ = h.join();
    }
    Ok(())
}

/// Version negotiation: the first frame must be `hello` with a
/// `version` we speak. Replies with the server hello carrying the
/// negotiated version and the connection's credit window.
fn handshake(shared: &ConnShared, f: &Frame, opts: ServerOpts) -> bool {
    if f.frame_type != FrameType::Hello {
        shared.send_error(f.id, "protocol: expected hello as the first frame");
        return false;
    }
    let version = crate::util::json::scan_u64(f.payload_str(), "version").unwrap_or(0);
    if version != VERSION {
        shared.send_error(f.id, &format!("protocol: unsupported version {version} (want {VERSION})"));
        return false;
    }
    let reply = Json::obj()
        .set("version", VERSION)
        .set("credits", opts.conn_inflight);
    shared.send(&Frame::json(FrameType::Hello, f.id, &reply))
}

/// Route one post-handshake frame.
fn dispatch(
    shared: &Arc<ConnShared>,
    f: Frame,
    stop: &AtomicBool,
    opts: ServerOpts,
    workers: &mut Vec<std::thread::JoinHandle<()>>,
) {
    match f.frame_type {
        FrameType::Request => handle_request(shared, f, stop, opts, workers),
        FrameType::Cancel => {
            // best-effort, no ack: the cancelled request still gets its
            // exactly-one terminal response (a `cancelled:` error)
            let mut inflight = shared.inflight.lock().unwrap();
            if let Some(flight) = inflight.get_mut(&f.id) {
                match flight.coord_id {
                    Some(cid) => {
                        shared.coord.cancel(cid);
                    }
                    None => flight.cancel_requested = true,
                }
            }
        }
        FrameType::Ping => {
            shared.send(&Frame::empty(FrameType::Pong, f.id));
        }
        FrameType::Pong => {} // any inbound frame already reset the reaper
        FrameType::Hello => {
            shared.send_error(f.id, "protocol: unexpected hello after negotiation");
        }
        FrameType::Response | FrameType::Step | FrameType::Error | FrameType::Credit => {
            shared.send_error(
                f.id,
                &format!("protocol: unexpected {} frame from client", f.frame_type.name()),
            );
        }
    }
}

/// Admit one `request` frame: control commands inline, generations
/// against the credit window then onto a worker thread.
fn handle_request(
    shared: &Arc<ConnShared>,
    f: Frame,
    stop: &AtomicBool,
    opts: ServerOpts,
    workers: &mut Vec<std::thread::JoinHandle<()>>,
) {
    let payload = f.payload_str();
    // lazy envelope scan: control commands are identified (and
    // generation requests passed through) without building the tree
    if scan_str(payload, "cmd").is_some() {
        let reply = match parse(payload) {
            Ok(j) => handle_control(&shared.coord, &j, stop)
                .unwrap_or_else(|| fail("cmd must be a string".into())),
            Err(e) => fail(format!("bad json: {e}")),
        };
        shared.send_response(f.id, &reply);
        return;
    }
    {
        let mut inflight = shared.inflight.lock().unwrap();
        if inflight.contains_key(&f.id) {
            // must NOT resolve the original request's handle: answered
            // as a protocol error frame, not a response
            shared.send_error(f.id, &format!("duplicate in-flight request id {}", f.id));
            // the duplicate frame still cost the sender a credit
            shared.send(&Frame::empty(FrameType::Credit, f.id));
            return;
        }
        if inflight.len() >= opts.conn_inflight {
            Metrics::inc(&shared.coord.metrics().v2_credit_rejections);
            let msg = format!(
                "overloaded: connection credit window exhausted \
                 ({} in flight, window {})",
                inflight.len(),
                opts.conn_inflight
            );
            let body = Json::obj()
                .set("ok", false)
                .set("overloaded", true)
                .set("error", msg)
                .to_string();
            shared.send_response(f.id, &body);
            return;
        }
        inflight.insert(f.id, Flight { coord_id: None, cancel_requested: false });
    }
    let shared2 = Arc::clone(shared);
    let handle = std::thread::Builder::new()
        .name(format!("smc-v2-req-{}", f.id))
        .spawn(move || run_request(&shared2, f.id, f.payload))
        .expect("spawn v2 request worker");
    workers.push(handle);
}

/// Drive one generation: parse → submit → stream steps → terminal
/// response → remove from the window → credit. Exactly one `response`
/// frame per request id on every path.
fn run_request(shared: &ConnShared, id: u64, payload: Vec<u8>) {
    let done = |body: &str| {
        shared.inflight.lock().unwrap().remove(&id);
        shared.send_response(id, body);
    };
    let j = match std::str::from_utf8(&payload).map_err(|e| e.to_string()).and_then(|s| {
        parse(s).map_err(|e| format!("bad json: {e}"))
    }) {
        Ok(j) => j,
        Err(e) => return done(&fail(e)),
    };
    let (request, wire_opts) = match parse_request(&j) {
        Ok(x) => x,
        Err(e) => return done(&fail(format!("{e}"))),
    };
    // wire-visible trace on request only; the coordinator auto-traces
    // for the flight recorder either way (docs/adr/009)
    let trace = if wire_opts.trace { TraceHandle::start() } else { TraceHandle::off() };
    trace.event("frame_in", payload.len() as u64, 0, 0, f64::NAN);
    let (progress, progress_rx): (Option<_>, Option<Receiver<Progress>>) = if wire_opts.stream {
        let (tx, rx) = channel();
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };
    let ticket = shared.coord.submit_opts(
        request,
        SubmitOpts { progress, deadline: wire_opts.deadline(), trace: trace.clone() },
    );
    // publish the coordinator id; honor a cancel that raced submission
    {
        let mut inflight = shared.inflight.lock().unwrap();
        match inflight.get_mut(&id) {
            Some(flight) => {
                flight.coord_id = Some(ticket.id);
                if flight.cancel_requested {
                    shared.coord.cancel(ticket.id);
                }
            }
            None => {
                // connection torn down during submit
                shared.coord.cancel(ticket.id);
                return;
            }
        }
    }
    if wire_opts.stream {
        let accepted = Json::obj().set("event", "accepted").set("ok", true).set("id", id);
        shared.send(&Frame::json(FrameType::Step, id, &accepted));
    }
    let result = loop {
        if let Some(rx) = &progress_rx {
            while let Ok(p) = rx.try_recv() {
                shared.send(&Frame::json(FrameType::Step, id, &step_event(id, &p)));
            }
        }
        if shared.dead.load(Ordering::SeqCst) {
            shared.coord.cancel(ticket.id);
            // drain the terminal reply so the coordinator's answered-
            // exactly-once accounting is preserved, then drop it
            let _ = ticket.reply.recv_timeout(Duration::from_secs(5));
            shared.inflight.lock().unwrap().remove(&id);
            return;
        }
        match ticket.reply.recv_timeout(Duration::from_millis(REPLY_POLL_MS)) {
            Ok(r) => break r,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break Err(crate::err!("coordinator shut down")),
        }
    };
    // step events that raced the final reply keep their order: they are
    // flushed before the terminal response frame
    if let Some(rx) = &progress_rx {
        while let Ok(p) = rx.try_recv() {
            shared.send(&Frame::json(FrameType::Step, id, &step_event(id, &p)));
        }
    }
    let ok = result.is_ok();
    let mut out = render_result_json(result, wire_opts);
    if trace.is_active() {
        // frame_out carries the pre-timeline body size; attaching the
        // timeline below inflates the actual response frame
        trace.event("frame_out", out.to_string().len() as u64, 0, 0, f64::NAN);
        if let Some(t) = trace.snapshot() {
            out = out.set("trace", t.to_json());
        }
        // idempotent catch-all; terminal coordinator paths already
        // sealed the flight-recorder entry with the precise outcome
        trace.finish(if ok { Outcome::Ok } else { Outcome::Failed });
    }
    done(&out.to_string());
}
