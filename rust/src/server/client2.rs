//! Production protocol-v2 client: connection pool, request-id table,
//! per-request wait handles, streaming step callbacks, cancel-by-id,
//! and reconnect-on-broken-pipe (docs/protocol.md §Protocol v2,
//! ADR-008).
//!
//! Each pooled connection runs one background reader thread that
//! demultiplexes inbound frames into per-request channels, so any
//! number of application threads can hold [`Handle`]s on the same
//! socket concurrently. Flow control mirrors the server: a submit
//! spends one credit from the window announced in the server `hello`,
//! `credit` frames earn it back, and a submit at zero credits fails
//! fast with a typed `overloaded:` error instead of queueing.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::Result;
use crate::util::json::{parse, scan_u64, Json};

use super::frame::{Decoded, Frame, FrameReader, FrameType, MAGIC, MAX_FRAME_LEN, VERSION};
use super::DEFAULT_IO_TIMEOUT;

/// Reader-thread poll tick (read timeout between liveness checks).
const POLL_MS: u64 = 50;

/// Tuning for [`Client2`].
#[derive(Clone, Copy, Debug)]
pub struct Client2Config {
    /// Pooled connections; requests round-robin across them.
    pub pool: usize,
    /// TCP connect + handshake budget per connection.
    pub connect_timeout: Duration,
    /// Liveness budget: if a connection with pending requests goes
    /// this long without any inbound frame (pings included), the
    /// connection is declared dead and every pending request fails
    /// with a typed `timeout:` error. Also the write timeout.
    pub io_timeout: Duration,
}

impl Default for Client2Config {
    fn default() -> Client2Config {
        Client2Config {
            pool: 1,
            connect_timeout: DEFAULT_IO_TIMEOUT,
            io_timeout: DEFAULT_IO_TIMEOUT,
        }
    }
}

/// One demultiplexed message for a pending request.
enum Msg {
    /// A `step` frame (streaming progress event).
    Step(Json),
    /// The terminal `response` frame's body.
    Done(Json),
    /// Protocol-level failure (connection lost, liveness timeout).
    Failed(String),
}

/// One live pooled connection.
struct Conn {
    writer: Mutex<TcpStream>,
    /// Request-id table: pending requests awaiting their response.
    pending: Mutex<HashMap<u64, Sender<Msg>>>,
    /// Remaining credit window (decremented at submit, replenished by
    /// `credit` frames).
    credits: Mutex<usize>,
    dead: AtomicBool,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Conn {
    fn fail_all(&self, msg: &str) {
        self.dead.store(true, Ordering::SeqCst);
        let mut pending = self.pending.lock().unwrap();
        for (_, tx) in pending.drain() {
            let _ = tx.send(Msg::Failed(msg.to_string()));
        }
    }

    fn send(&self, f: &Frame) -> bool {
        let mut w = self.writer.lock().unwrap();
        let ok = f.write_to(&mut *w).and_then(|_| w.flush()).is_ok();
        if !ok {
            self.dead.store(true, Ordering::SeqCst);
        }
        ok
    }
}

/// A pending request: blocks on [`Handle::wait`] for the terminal
/// response, or streams step events via [`Handle::wait_streaming`].
pub struct Handle {
    /// The client-chosen wire request id.
    pub id: u64,
    rx: Receiver<Msg>,
    conn: Arc<Conn>,
}

impl Handle {
    /// Block until the terminal response. Application-level failures
    /// come back as the reply object (`ok: false` + flags, exactly as
    /// v1); protocol-level failures (timeout, lost connection) are
    /// typed `Err`s.
    pub fn wait(self) -> Result<Json> {
        self.wait_streaming(|_| {})
    }

    /// Like [`Handle::wait`], invoking `on_event` for every `accepted`
    /// / `step` event frame that precedes the response.
    pub fn wait_streaming(self, mut on_event: impl FnMut(&Json)) -> Result<Json> {
        loop {
            match self.rx.recv() {
                Ok(Msg::Step(ev)) => on_event(&ev),
                Ok(Msg::Done(reply)) => return Ok(reply),
                Ok(Msg::Failed(msg)) => return Err(crate::err!("{msg}")),
                Err(_) => return Err(crate::err!("connection lost: reader gone")),
            }
        }
    }

    /// Best-effort cancel of this request (`cancel` frame). The
    /// request still resolves exactly once — normally with a
    /// `cancelled:` error response.
    pub fn cancel(&self) {
        self.conn.send(&Frame::empty(FrameType::Cancel, self.id));
    }
}

/// Pooled, multiplexing protocol-v2 client.
pub struct Client2 {
    addr: SocketAddr,
    cfg: Client2Config,
    slots: Vec<Mutex<Option<Arc<Conn>>>>,
    next_slot: AtomicUsize,
    next_id: AtomicU64,
}

impl Client2 {
    /// Connect with [`Client2Config::default`] (pool of 1, 30s
    /// timeouts), performing the first handshake eagerly so a dead
    /// server fails here rather than on first use.
    pub fn connect(addr: &SocketAddr) -> Result<Client2> {
        Client2::with_config(addr, Client2Config::default())
    }

    /// Connect with explicit tuning; the slot-0 handshake runs eagerly.
    pub fn with_config(addr: &SocketAddr, cfg: Client2Config) -> Result<Client2> {
        let pool = cfg.pool.max(1);
        let client = Client2 {
            addr: *addr,
            cfg: Client2Config { pool, ..cfg },
            slots: (0..pool).map(|_| Mutex::new(None)).collect(),
            next_slot: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
        };
        client.ensure_conn(0)?;
        Ok(client)
    }

    /// Handshake a fresh connection: magic, client hello, server hello
    /// (which announces the credit window), then the reader thread.
    fn open_conn(&self) -> Result<Arc<Conn>> {
        let stream = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)
            .map_err(|e| crate::err!("timeout: connect {}: {e}", self.addr))?;
        stream.set_write_timeout(Some(self.cfg.io_timeout))?;
        stream.set_read_timeout(Some(Duration::from_millis(POLL_MS)))?;
        let mut sock = stream.try_clone()?;
        {
            let mut w = &stream;
            w.write_all(&MAGIC)?;
            Frame::json(FrameType::Hello, 0, &Json::obj().set("version", VERSION))
                .write_to(&mut w)?;
            w.flush()?;
        }
        // wait for the server hello within the connect budget
        let mut reader = FrameReader::new(MAX_FRAME_LEN);
        let deadline = Instant::now() + self.cfg.connect_timeout;
        let hello = loop {
            if Instant::now() >= deadline {
                return Err(crate::err!(
                    "timeout: no hello from {} within {:?}",
                    self.addr,
                    self.cfg.connect_timeout
                ));
            }
            let mut buf = [0u8; 1024];
            match sock.read(&mut buf) {
                Ok(0) => return Err(crate::err!("handshake: server closed the connection")),
                Ok(n) => {
                    reader.extend(&buf[..n]);
                    match reader.decode() {
                        Decoded::Frame(f) if f.frame_type == FrameType::Hello => break f,
                        Decoded::Frame(f) => {
                            return Err(crate::err!(
                                "handshake: expected hello, got {} frame: {}",
                                f.frame_type.name(),
                                f.payload_str()
                            ))
                        }
                        Decoded::Malformed(e) => return Err(crate::err!("handshake: {e}")),
                        Decoded::Incomplete => {}
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(e) => return Err(e.into()),
            }
        };
        let credits = scan_u64(hello.payload_str(), "credits").unwrap_or(1) as usize;
        let conn = Arc::new(Conn {
            writer: Mutex::new(stream),
            pending: Mutex::new(HashMap::new()),
            credits: Mutex::new(credits),
            dead: AtomicBool::new(false),
            reader: Mutex::new(None),
        });
        let conn2 = Arc::clone(&conn);
        let io_timeout = self.cfg.io_timeout;
        let handle = std::thread::Builder::new()
            .name("smc-client2-reader".into())
            .spawn(move || reader_loop(&conn2, sock, reader, io_timeout))?;
        *conn.reader.lock().unwrap() = Some(handle);
        Ok(conn)
    }

    /// The live connection for a slot, reconnecting if absent or dead.
    fn ensure_conn(&self, slot: usize) -> Result<Arc<Conn>> {
        let mut guard = self.slots[slot].lock().unwrap();
        if let Some(conn) = guard.as_ref() {
            if !conn.dead.load(Ordering::SeqCst) {
                return Ok(Arc::clone(conn));
            }
        }
        let conn = self.open_conn()?;
        *guard = Some(Arc::clone(&conn));
        Ok(conn)
    }

    /// Submit one request frame and return its wait handle. Retries
    /// once on a fresh connection if the write hits a broken pipe.
    pub fn submit(&self, req: &Json) -> Result<Handle> {
        let slot = self.next_slot.fetch_add(1, Ordering::Relaxed) % self.cfg.pool;
        let mut last_err = None;
        for _attempt in 0..2 {
            let conn = match self.ensure_conn(slot) {
                Ok(c) => c,
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            };
            {
                let mut credits = conn.credits.lock().unwrap();
                if *credits == 0 {
                    return Err(crate::err!(
                        "overloaded: client credit window exhausted (0 of the \
                         server-announced window left on this connection)"
                    ));
                }
                *credits -= 1;
            }
            let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
            let (tx, rx) = channel();
            conn.pending.lock().unwrap().insert(id, tx);
            let frame = Frame::new(FrameType::Request, id, req.to_string().into_bytes());
            if conn.send(&frame) {
                return Ok(Handle { id, rx, conn });
            }
            // broken pipe: unwind this attempt and retry on a fresh
            // connection (ensure_conn sees the dead flag)
            conn.pending.lock().unwrap().remove(&id);
            last_err = Some(crate::err!("connection lost: write failed"));
        }
        Err(last_err.unwrap_or_else(|| crate::err!("connection lost: submit failed")))
    }

    /// Send one request, block for its reply (v1 `Client::call` shape).
    pub fn call(&self, req: &Json) -> Result<Json> {
        self.submit(req)?.wait()
    }

    /// Streaming call: `stream: true` is added to `req`, `on_event`
    /// runs for every `accepted` / `step` event, and the final reply
    /// object is returned.
    pub fn call_streaming(&self, req: &Json, on_event: impl FnMut(&Json)) -> Result<Json> {
        let req = req.clone().set("stream", true);
        self.submit(&req)?.wait_streaming(on_event)
    }

    /// Best-effort cancel-by-id across the pool: emits a `cancel`
    /// frame on the connection whose pending table owns `id`. Returns
    /// whether the id was still pending here.
    pub fn cancel(&self, id: u64) -> Result<bool> {
        for slot in &self.slots {
            let conn = match slot.lock().unwrap().as_ref() {
                Some(c) => Arc::clone(c),
                None => continue,
            };
            if conn.pending.lock().unwrap().contains_key(&id) {
                conn.send(&Frame::empty(FrameType::Cancel, id));
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Keepalive round-trip on one pooled connection.
    pub fn ping(&self) -> Result<bool> {
        let slot = self.next_slot.fetch_add(1, Ordering::Relaxed) % self.cfg.pool;
        let conn = self.ensure_conn(slot)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let (tx, rx) = channel();
        conn.pending.lock().unwrap().insert(id, tx);
        if !conn.send(&Frame::empty(FrameType::Ping, id)) {
            conn.pending.lock().unwrap().remove(&id);
            return Err(crate::err!("connection lost: ping write failed"));
        }
        match rx.recv_timeout(self.cfg.io_timeout) {
            Ok(Msg::Done(_)) => Ok(true),
            Ok(Msg::Failed(msg)) => Err(crate::err!("{msg}")),
            Ok(Msg::Step(_)) => Ok(false),
            Err(_) => {
                conn.pending.lock().unwrap().remove(&id);
                Err(crate::err!("timeout: no pong within {:?}", self.cfg.io_timeout))
            }
        }
    }

    /// The server's one-line metrics summary (`{"cmd":"metrics"}`).
    pub fn metrics_summary(&self) -> Result<String> {
        let r = self.call(&Json::obj().set("cmd", "metrics"))?;
        Ok(r.get("summary").and_then(|v| v.as_str()).unwrap_or("").to_string())
    }

    /// Ops hook: shut down every pooled socket in place *without*
    /// dropping the pool state, so the next submit exercises the
    /// broken-pipe reconnect path (also used by the reconnect test).
    pub fn reset(&self) {
        for slot in &self.slots {
            if let Some(conn) = slot.lock().unwrap().as_ref() {
                let _ = conn.writer.lock().unwrap().shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Drop for Client2 {
    fn drop(&mut self) {
        for slot in &self.slots {
            let conn = slot.lock().unwrap().take();
            if let Some(conn) = conn {
                conn.dead.store(true, Ordering::SeqCst);
                let _ = conn.writer.lock().unwrap().shutdown(std::net::Shutdown::Both);
                let handle = conn.reader.lock().unwrap().take();
                if let Some(h) = handle {
                    let _ = h.join();
                }
            }
        }
    }
}

/// Per-connection reader: demultiplexes inbound frames into the
/// pending table and enforces the liveness budget.
fn reader_loop(conn: &Conn, mut sock: TcpStream, mut reader: FrameReader, io_timeout: Duration) {
    let mut buf = [0u8; 8192];
    let mut last_frame = Instant::now();
    let mut pinged = false;
    loop {
        if conn.dead.load(Ordering::SeqCst) {
            conn.fail_all("connection lost: client shut down");
            return;
        }
        match sock.read(&mut buf) {
            Ok(0) => {
                conn.fail_all("connection lost: server closed the connection");
                return;
            }
            Ok(n) => {
                reader.extend(&buf[..n]);
                last_frame = Instant::now();
                pinged = false;
                loop {
                    match reader.decode() {
                        Decoded::Incomplete => break,
                        Decoded::Malformed(e) => {
                            // a malformed server frame means the stream
                            // is unrecoverably desynced for us
                            conn.fail_all(&format!("protocol: {e}"));
                            return;
                        }
                        Decoded::Frame(f) => handle_frame(conn, f),
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                let waiting = !conn.pending.lock().unwrap().is_empty();
                if !waiting {
                    last_frame = Instant::now(); // budget runs only with work pending
                    continue;
                }
                let quiet = last_frame.elapsed();
                if quiet >= io_timeout {
                    conn.fail_all(&format!("timeout: no frames within {io_timeout:?}"));
                    return;
                }
                if quiet >= io_timeout / 2 && !pinged {
                    // probe once per quiet spell; any inbound frame
                    // (the pong included) refreshes the budget
                    conn.send(&Frame::empty(FrameType::Ping, 0));
                    pinged = true;
                }
            }
            Err(e) => {
                conn.fail_all(&format!("connection lost: {e}"));
                return;
            }
        }
    }
}

/// Route one inbound frame to its pending request (or the connection).
fn handle_frame(conn: &Conn, f: Frame) {
    match f.frame_type {
        FrameType::Response => {
            let tx = conn.pending.lock().unwrap().remove(&f.id);
            if let Some(tx) = tx {
                let msg = match parse(f.payload_str()) {
                    Ok(j) => Msg::Done(j),
                    Err(e) => Msg::Failed(format!("bad reply: {e} ({:?})", f.payload_str())),
                };
                let _ = tx.send(msg);
            }
        }
        FrameType::Step => {
            let pending = conn.pending.lock().unwrap();
            if let (Some(tx), Some(ev)) = (pending.get(&f.id), f.payload_json()) {
                let _ = tx.send(Msg::Step(ev));
            }
        }
        FrameType::Credit => {
            *conn.credits.lock().unwrap() += 1;
        }
        FrameType::Ping => {
            conn.send(&Frame::empty(FrameType::Pong, f.id));
        }
        FrameType::Pong => {
            // a pending id means a synchronous Client2::ping round-trip
            let tx = conn.pending.lock().unwrap().remove(&f.id);
            if let Some(tx) = tx {
                let _ = tx.send(Msg::Done(Json::obj().set("ok", true).set("pong", true)));
            }
        }
        // error frames are protocol-level notices and deliberately do
        // NOT resolve handles (e.g. a duplicate-id error must not
        // resolve the original request); hello after handshake and
        // client-only types are ignored the same way
        FrameType::Error | FrameType::Hello | FrameType::Request | FrameType::Cancel => {}
    }
}
