//! Protocol v2 frame codec (docs/protocol.md §Protocol v2, ADR-008).
//!
//! A v2 connection opens with the 4-byte magic `SMC2`, then carries a
//! stream of length-prefixed frames:
//!
//! ```text
//! +----------------+--------------+----------------------+---------+
//! | payload len    | frame type   | request id           | payload |
//! | u32 LE (4 B)   | u8 (1 B)     | u64 LE (8 B)         | len B   |
//! +----------------+--------------+----------------------+---------+
//! ```
//!
//! Payloads are UTF-8 JSON (the same envelopes as protocol v1), kept
//! small and debuggable; the framing is what buys multiplexing, not a
//! binary body encoding. Decoding is strict: an oversized declared
//! length or an unknown frame type is reported as a typed
//! [`FrameError`] and the offending frame's bytes are *skipped* so the
//! connection's other in-flight streams survive (the mux layer answers
//! with an `error` frame instead of closing the socket).

use crate::util::json::Json;
use std::io::{self, Write};

/// Connection preamble distinguishing v2 from v1 JSON-lines. v1 lines
/// always start with `{` (or whitespace), so sniffing the first byte
/// on the shared listener is unambiguous.
pub const MAGIC: [u8; 4] = *b"SMC2";

/// Protocol version carried in the `hello` negotiation frame.
pub const VERSION: u64 = 2;

/// Default cap on a single frame's declared payload length. Anything
/// larger is decode-rejected before buffering, so a hostile or corrupt
/// length prefix cannot balloon memory.
pub const MAX_FRAME_LEN: usize = 16 * 1024 * 1024;

/// Fixed header size: 4-byte length + 1-byte type + 8-byte id.
pub const HEADER_LEN: usize = 13;

/// Frame discriminator (one byte on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameType {
    /// Version negotiation; first frame in each direction.
    Hello = 0,
    /// Client → server: submit a generation or control command.
    Request = 1,
    /// Server → client: terminal reply for a request id.
    Response = 2,
    /// Server → client: one streaming progress event.
    Step = 3,
    /// Client → server: cancel the generation with this id.
    Cancel = 4,
    /// Keepalive probe (either direction).
    Ping = 5,
    /// Keepalive reply (either direction).
    Pong = 6,
    /// Server → client: protocol-level error tied to an id (or 0).
    Error = 7,
    /// Flow control: one unit of the credit window returned.
    Credit = 8,
}

impl FrameType {
    /// Decode a wire byte; `None` for unknown discriminators.
    pub fn from_byte(b: u8) -> Option<FrameType> {
        Some(match b {
            0 => FrameType::Hello,
            1 => FrameType::Request,
            2 => FrameType::Response,
            3 => FrameType::Step,
            4 => FrameType::Cancel,
            5 => FrameType::Ping,
            6 => FrameType::Pong,
            7 => FrameType::Error,
            8 => FrameType::Credit,
            _ => return None,
        })
    }

    /// The wire byte for this type.
    pub fn byte(self) -> u8 {
        self as u8
    }

    /// Human-readable name used in error messages and docs.
    pub fn name(self) -> &'static str {
        match self {
            FrameType::Hello => "hello",
            FrameType::Request => "request",
            FrameType::Response => "response",
            FrameType::Step => "step",
            FrameType::Cancel => "cancel",
            FrameType::Ping => "ping",
            FrameType::Pong => "pong",
            FrameType::Error => "error",
            FrameType::Credit => "credit",
        }
    }
}

/// One decoded frame: type, client-chosen request id, raw payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Frame discriminator.
    pub frame_type: FrameType,
    /// Client-chosen request id (0 for connection-scoped frames).
    pub id: u64,
    /// Raw payload bytes (UTF-8 JSON for non-empty payloads).
    pub payload: Vec<u8>,
}

impl Frame {
    /// A frame with a raw byte payload.
    pub fn new(frame_type: FrameType, id: u64, payload: Vec<u8>) -> Frame {
        Frame { frame_type, id, payload }
    }

    /// A frame whose payload is a serialized JSON document.
    pub fn json(frame_type: FrameType, id: u64, doc: &Json) -> Frame {
        Frame::new(frame_type, id, doc.to_string().into_bytes())
    }

    /// An empty-payload frame (ping/pong/cancel/credit).
    pub fn empty(frame_type: FrameType, id: u64) -> Frame {
        Frame::new(frame_type, id, Vec::new())
    }

    /// Parse the payload as JSON; `None` if empty or malformed.
    pub fn payload_json(&self) -> Option<Json> {
        if self.payload.is_empty() {
            return None;
        }
        let s = std::str::from_utf8(&self.payload).ok()?;
        crate::util::json::parse(s).ok()
    }

    /// Payload as a `&str` (empty string for empty payloads).
    pub fn payload_str(&self) -> &str {
        std::str::from_utf8(&self.payload).unwrap_or("")
    }

    /// Serialize header + payload into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.payload.len());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.push(self.frame_type.byte());
        out.extend_from_slice(&self.id.to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Write the encoded frame to `w` in one `write_all` (callers hold
    /// the egress lock across this, so interleaved streams never
    /// corrupt each other's frames).
    pub fn write_to(&self, w: &mut dyn Write) -> io::Result<()> {
        w.write_all(&self.encode())
    }
}

/// Typed decode failure; the mux layer renders these into `error`
/// frames with stable `frame:`-prefixed messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Declared payload length exceeds the configured cap.
    Oversized {
        /// Declared payload length from the header.
        len: usize,
        /// The cap it exceeded.
        max: usize,
    },
    /// Unknown frame-type discriminator byte.
    UnknownType(u8),
    /// Stream ended mid-frame (header or payload truncated).
    Truncated,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len, max } => {
                write!(f, "frame: declared payload length {len} exceeds max {max}")
            }
            FrameError::UnknownType(b) => write!(f, "frame: unknown frame type {b}"),
            FrameError::Truncated => write!(f, "frame: stream truncated mid-frame"),
        }
    }
}

/// One `FrameReader::decode` outcome.
#[derive(Debug, PartialEq)]
pub enum Decoded {
    /// A complete, well-formed frame.
    Frame(Frame),
    /// A malformed frame was encountered; its bytes are being skipped
    /// and subsequent frames will still decode.
    Malformed(FrameError),
    /// Not enough buffered bytes yet.
    Incomplete,
}

/// Incremental frame decoder over a byte stream.
///
/// Feed raw reads in with [`FrameReader::extend`], then drain complete
/// frames with [`FrameReader::decode`] until it returns
/// [`Decoded::Incomplete`]. Works with short reads and read timeouts:
/// no bytes are ever lost between calls.
///
/// Malformed frames (oversized length, unknown type) are reported once
/// via [`Decoded::Malformed`] and their declared extent is then
/// discarded as bytes arrive, so a single bad frame cannot poison the
/// frames behind it.
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
    /// Bytes still to discard for a previously-reported malformed frame.
    discard: usize,
}

impl FrameReader {
    /// A decoder enforcing `max_frame` as the payload-length cap.
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max_frame, discard: 0 }
    }

    /// Append freshly-read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// True if the buffer holds a partial frame (used at EOF to tell a
    /// clean close from a truncated one).
    pub fn is_mid_frame(&self) -> bool {
        !self.buf.is_empty() || self.discard > 0
    }

    /// Try to decode the next frame from the buffer.
    pub fn decode(&mut self) -> Decoded {
        // finish discarding a previously-reported malformed frame
        if self.discard > 0 {
            let n = self.discard.min(self.buf.len());
            self.buf.drain(..n);
            self.discard -= n;
            if self.discard > 0 {
                return Decoded::Incomplete;
            }
        }
        if self.buf.len() < 4 {
            return Decoded::Incomplete;
        }
        let len = u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > self.max_frame {
            // reject on the declared length alone — don't buffer a
            // hostile 4 GiB frame waiting for its type byte
            let err = FrameError::Oversized { len, max: self.max_frame };
            self.buf.drain(..4);
            self.discard = 1 + 8 + len; // type + id + payload still inbound
            return Decoded::Malformed(err);
        }
        if self.buf.len() < 5 {
            return Decoded::Incomplete;
        }
        let type_byte = self.buf[4];
        let Some(frame_type) = FrameType::from_byte(type_byte) else {
            let err = FrameError::UnknownType(type_byte);
            self.buf.drain(..5);
            self.discard = 8 + len; // id + payload still inbound
            return Decoded::Malformed(err);
        };
        if self.buf.len() < HEADER_LEN + len {
            return Decoded::Incomplete;
        }
        let mut id_bytes = [0u8; 8];
        id_bytes.copy_from_slice(&self.buf[5..13]);
        let id = u64::from_le_bytes(id_bytes);
        let payload = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.buf.drain(..HEADER_LEN + len);
        Decoded::Frame(Frame { frame_type, id, payload })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let types = [
            FrameType::Hello,
            FrameType::Request,
            FrameType::Response,
            FrameType::Step,
            FrameType::Cancel,
            FrameType::Ping,
            FrameType::Pong,
            FrameType::Error,
            FrameType::Credit,
        ];
        let mut r = FrameReader::new(MAX_FRAME_LEN);
        for (i, t) in types.iter().enumerate() {
            let f = Frame::new(*t, i as u64 + 1, format!("payload-{i}").into_bytes());
            r.extend(&f.encode());
            match r.decode() {
                Decoded::Frame(got) => assert_eq!(got, f),
                other => panic!("expected frame, got {other:?}"),
            }
        }
        assert_eq!(r.decode(), Decoded::Incomplete);
        assert!(!r.is_mid_frame());
    }

    #[test]
    fn incremental_byte_at_a_time() {
        let f = Frame::json(
            FrameType::Request,
            42,
            &Json::obj().set("cmd", Json::Str("ping".into())),
        );
        let bytes = f.encode();
        let mut r = FrameReader::new(MAX_FRAME_LEN);
        for (i, b) in bytes.iter().enumerate() {
            r.extend(&[*b]);
            if i + 1 < bytes.len() {
                assert_eq!(r.decode(), Decoded::Incomplete, "byte {i}");
                assert!(r.is_mid_frame());
            }
        }
        match r.decode() {
            Decoded::Frame(got) => assert_eq!(got, f),
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn oversized_is_reported_then_skipped() {
        let mut r = FrameReader::new(64);
        // header declaring a 1000-byte payload, followed by its bytes,
        // followed by a valid ping frame
        let mut bad = Vec::new();
        bad.extend_from_slice(&1000u32.to_le_bytes());
        bad.push(FrameType::Request.byte());
        bad.extend_from_slice(&7u64.to_le_bytes());
        bad.extend_from_slice(&vec![b'x'; 1000]);
        let good = Frame::empty(FrameType::Ping, 9);
        r.extend(&bad);
        r.extend(&good.encode());
        match r.decode() {
            Decoded::Malformed(FrameError::Oversized { len, max }) => {
                assert_eq!(len, 1000);
                assert_eq!(max, 64);
            }
            other => panic!("expected oversized, got {other:?}"),
        }
        // the bad frame's bytes are discarded; the ping decodes next
        match r.decode() {
            Decoded::Frame(got) => assert_eq!(got, good),
            other => panic!("expected ping after skip, got {other:?}"),
        }
    }

    #[test]
    fn oversized_reported_before_payload_arrives() {
        let mut r = FrameReader::new(64);
        // only the 4-byte length prefix has arrived
        r.extend(&(u32::MAX).to_le_bytes());
        match r.decode() {
            Decoded::Malformed(FrameError::Oversized { .. }) => {}
            other => panic!("expected early oversized, got {other:?}"),
        }
    }

    #[test]
    fn unknown_type_is_reported_then_skipped() {
        let mut r = FrameReader::new(MAX_FRAME_LEN);
        let mut bad = Vec::new();
        bad.extend_from_slice(&3u32.to_le_bytes());
        bad.push(99); // no such type
        bad.extend_from_slice(&5u64.to_le_bytes());
        bad.extend_from_slice(b"abc");
        let good = Frame::empty(FrameType::Pong, 6);
        r.extend(&bad);
        r.extend(&good.encode());
        match r.decode() {
            Decoded::Malformed(FrameError::UnknownType(99)) => {}
            other => panic!("expected unknown type, got {other:?}"),
        }
        match r.decode() {
            Decoded::Frame(got) => assert_eq!(got, good),
            other => panic!("expected pong after skip, got {other:?}"),
        }
    }

    #[test]
    fn payload_json_roundtrip() {
        let doc = Json::obj()
            .set("cmd", Json::Str("generate".into()))
            .set("steps", Json::Num(8.0));
        let f = Frame::json(FrameType::Request, 1, &doc);
        assert_eq!(f.payload_json().unwrap().to_string(), doc.to_string());
        assert!(Frame::empty(FrameType::Ping, 1).payload_json().is_none());
    }

    #[test]
    fn error_messages_are_typed() {
        let e = FrameError::Oversized { len: 100, max: 10 };
        assert!(e.to_string().starts_with("frame: "));
        assert!(FrameError::UnknownType(3).to_string().starts_with("frame: "));
        assert!(FrameError::Truncated.to_string().starts_with("frame: "));
    }
}
