//! TCP JSON-lines serving front-end + client library.
//!
//! One JSON object per line in each direction. Request fields:
//! `family`, `steps`, `solver`, `policy`, `cfg`, `seed`, `compute`
//! (weight-matmul precision: `f32` default, or `f16` / `bf16` /
//! `int8`), `priority` (`interactive` — the default — or `batch`:
//! batch-class work is preemptible and yields to interactive traffic
//! at solver-step boundaries, docs/adr/007), and either
//! `label` (image) or `prompt_ids` (audio/video); `return_latent`
//! includes the generated latent in the response; `stream: true`
//! switches the reply to streaming mode (one `{"event":"step",…}` line
//! per solver step, then the final result line); `deadline_ms` (+
//! `deadline_policy`) attaches a latency budget; `trace: true` returns
//! the request's recorded timeline as a `"trace"` object on the final
//! reply (docs/adr/009; requires tracing enabled, i.e.
//! `SMOOTHCACHE_TRACE` not `off`). Control commands:
//! `{"cmd": "ping"}`, `{"cmd": "metrics"}` (plus `"format":"json"` for
//! a structured [`crate::coordinator::Metrics::summary_json`] reply),
//! `{"cmd": "dump"}` (the flight recorder's retained timelines),
//! `{"cmd": "cancel", "id": N}`, `{"cmd": "shutdown"}`.
//! Failures are answered in-line as `{"ok": false, "error": "…"}`;
//! admission-control rejections (the coordinator's work queue at
//! `--queue-depth`, see [`crate::coordinator::queue`]) additionally
//! carry `"overloaded": true`, cancelled requests `"cancelled": true`,
//! and deadline rejections `"deadline_missed": true`, so clients can
//! tell transient and client-initiated outcomes from real failures.
//! A connection that disappears mid-generation has its in-flight
//! request cancelled (work stops at the next solver step; the
//! admission slot frees) — see [`crate::coordinator::cancel`].
//!
//! The full wire contract (field semantics, defaults, batching
//! guarantees, streaming events, error + overload shapes,
//! metrics-summary fields) is specified in `docs/protocol.md` at the
//! repository root — keep the two in sync when evolving the protocol.
//! The `policy` vocabulary is the registry in
//! [`crate::cache::plan::registry`]: the doc's policy table is
//! generated from it (and pinned by a test), so adding a policy there
//! is all a new wire value needs.
//!
//! The same listener also speaks **protocol v2** (docs/protocol.md
//! §Protocol v2, docs/adr/008): a connection that opens with the
//! 4-byte magic `SMC2` is handed to [`mux::handle_conn_v2`], which
//! multiplexes many concurrent generations over length-prefixed frames
//! ([`frame`]) with per-connection credit flow control; [`Client2`] is
//! the pooled production client for it. Any other first byte falls
//! through to the v1 JSON-lines loop above, so v1 stays the default
//! and every existing client keeps working.

pub mod client2;
pub mod frame;
pub mod mux;

pub use client2::{Client2, Client2Config, Handle};

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read as _, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

use crate::util::error::{Context, Result};

use crate::coordinator::{
    Coordinator, Deadline, DeadlinePolicy, Policy, PriorityClass, Progress, Request, Response,
    SubmitOpts,
};
use crate::model::Cond;
use crate::obs::{recorder, Outcome, TraceHandle};
use crate::solvers::SolverKind;
use crate::tensor::ComputeMode;
use crate::util::json::{parse, Json};
use crate::util::threadpool::ThreadPool;

/// Per-request wire options that ride beside the [`Request`] proper:
/// response shaping (`return_latent`, `stream`) and the optional
/// deadline.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireOpts {
    /// Include the generated latent values in the final reply.
    pub return_latent: bool,
    /// Streaming mode: emit an `accepted` line, one `step` event line
    /// per solver step, then the final result line.
    pub stream: bool,
    /// Latency budget in milliseconds, measured from submission.
    pub deadline_ms: Option<u64>,
    /// What to do with work that misses the deadline.
    pub deadline_policy: DeadlinePolicy,
    /// Return the request's recorded timeline as a `"trace"` object on
    /// the final reply (docs/adr/009).
    pub trace: bool,
}

impl WireOpts {
    fn deadline(&self) -> Option<Deadline> {
        self.deadline_ms
            .map(|ms| Deadline::after(Duration::from_millis(ms), self.deadline_policy))
    }
}

/// Parse one request line into a coordinator [`Request`] + [`WireOpts`].
pub fn parse_request(j: &Json) -> Result<(Request, WireOpts)> {
    let family = j
        .get("family")
        .and_then(|v| v.as_str())
        .ok_or_else(|| crate::err!("missing family"))?
        .to_string();
    let steps = j.get("steps").and_then(|v| v.as_usize()).unwrap_or(50);
    let solver_name = j.get("solver").and_then(|v| v.as_str()).unwrap_or("ddim");
    let solver =
        SolverKind::parse(solver_name).ok_or_else(|| crate::err!("unknown solver {solver_name}"))?;
    let policy_s = j.get("policy").and_then(|v| v.as_str()).unwrap_or("no-cache");
    let policy = Policy::parse(policy_s)?;
    let compute = match j.get("compute") {
        None => ComputeMode::F32,
        Some(v) => {
            let s = v.as_str().ok_or_else(|| {
                crate::err!("compute must be a string, got {}", v.to_string())
            })?;
            ComputeMode::parse(s)?
        }
    };
    let cfg_scale = j.get("cfg").and_then(|v| v.as_f64()).unwrap_or(1.0) as f32;
    // seeds are parsed losslessly: an `as u64` cast used to silently
    // truncate negative and mangle > 2^53 values, changing the latent
    // the client thought it pinned
    let seed = match j.get("seed") {
        None => 0,
        Some(v) => v.as_u64().ok_or_else(|| {
            crate::err!("seed must be a non-negative integer <= 2^53 - 1, got {}", v.to_string())
        })?,
    };
    let cond = if let Some(l) = j.get("label").and_then(|v| v.as_f64()) {
        Cond::Label(vec![l as i32])
    } else if let Some(p) = j.get("prompt_ids") {
        // as_f64_vec is all-or-None: a mixed array like [1,"x",3] is a
        // typed wire error, never a silently-shortened prompt
        let ids = p.as_f64_vec().ok_or_else(|| {
            crate::err!("prompt_ids must be an array of numbers, got {}", p.to_string())
        })?;
        Cond::Prompt(ids.into_iter().map(|x| x as i32).collect())
    } else {
        return Err(crate::err!("need label or prompt_ids"));
    };
    let return_latent = j.get("return_latent").and_then(|v| v.as_bool()).unwrap_or(false);
    let stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    let trace = j.get("trace").and_then(|v| v.as_bool()).unwrap_or(false);
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => Some(v.as_u64().filter(|&ms| ms > 0).ok_or_else(|| {
            crate::err!("deadline_ms must be a positive integer, got {}", v.to_string())
        })?),
    };
    let deadline_policy = match j.get("deadline_policy").and_then(|v| v.as_str()) {
        None => DeadlinePolicy::BestEffort,
        Some(s) => DeadlinePolicy::parse(s)
            .ok_or_else(|| crate::err!("deadline_policy must be best-effort or reject, got {s:?}"))?,
    };
    let priority = match j.get("priority") {
        None => PriorityClass::default(),
        Some(v) => {
            let s = v.as_str().ok_or_else(|| {
                crate::err!("priority must be a string, got {}", v.to_string())
            })?;
            PriorityClass::parse(s)
                .ok_or_else(|| crate::err!("priority must be interactive or batch, got {s:?}"))?
        }
    };
    Ok((
        Request { id: 0, family, cond, solver, steps, cfg_scale, seed, policy, compute, priority },
        WireOpts { return_latent, stream, deadline_ms, deadline_policy, trace },
    ))
}

fn fail(msg: String) -> String {
    Json::obj().set("ok", false).set("error", msg).to_string()
}

/// Handle a control command (a line with a `cmd` field). `None` when
/// the line is not a control command.
fn handle_control(coord: &Coordinator, j: &Json, stop: &AtomicBool) -> Option<String> {
    let cmd = j.get("cmd").and_then(|v| v.as_str())?;
    Some(match cmd {
        "ping" => Json::obj().set("ok", true).set("pong", true).to_string(),
        "metrics" => match j.get("format").and_then(|v| v.as_str()) {
            Some("json") => Json::obj()
                .set("ok", true)
                .set("metrics", coord.metrics().summary_json())
                .to_string(),
            None | Some("text") => Json::obj()
                .set("ok", true)
                .set("summary", coord.metrics().summary())
                .to_string(),
            Some(other) => fail(format!("metrics format must be text or json, got {other:?}")),
        },
        "dump" => recorder().to_json().set("ok", true).to_string(),
        "cancel" => match j.get("id").and_then(|v| v.as_u64()) {
            Some(id) => Json::obj()
                .set("ok", true)
                .set("id", id)
                .set("cancelled", coord.cancel(id))
                .to_string(),
            None => fail("cancel needs an integer id".into()),
        },
        "shutdown" => {
            stop.store(true, Ordering::SeqCst);
            Json::obj().set("ok", true).set("stopping", true).to_string()
        }
        other => fail(format!("unknown cmd {other}")),
    })
}

/// Render the final reply for a generation outcome. Error replies
/// carry machine-readable flags next to `error`: `overloaded` (queue
/// admission, transient), `cancelled` (client-initiated), and
/// `deadline_missed` (reject-late deadline). Returned as [`Json`] so
/// the traced path can append the timeline object before serializing.
fn render_result_json(result: Result<Response>, opts: WireOpts) -> Json {
    match result {
        Ok(resp) => {
            let mut out = Json::obj();
            if opts.stream {
                out = out.set("event", "done");
            }
            out = out
                .set("ok", true)
                .set("id", resp.id)
                .set(
                    "latent_shape",
                    resp.latent.shape.iter().map(|&d| Json::Num(d as f64)).collect::<Vec<_>>(),
                )
                .set("batch_size", resp.batch_size)
                .set("steps", resp.steps_completed)
                .set("queue_s", resp.queue_seconds)
                .set("exec_s", resp.exec_seconds)
                .set("total_s", resp.total_seconds)
                .set("skip_fraction", resp.gen_stats.skip_fraction());
            if resp.deadline_missed {
                out = out.set("deadline_missed", true);
            }
            if opts.return_latent {
                out = out.set(
                    "latent",
                    resp.latent.data.iter().map(|&v| Json::Num(v as f64)).collect::<Vec<_>>(),
                );
            }
            out
        }
        Err(e) => {
            let msg = format!("{e}");
            let mut out = Json::obj();
            if opts.stream {
                out = out.set("event", "done");
            }
            out = out.set("ok", false);
            if msg.starts_with("overloaded:") {
                // queue-admission rejection: transient — back off, retry
                out = out.set("overloaded", true);
            } else if msg.starts_with("cancelled:") {
                out = out.set("cancelled", true);
            } else if msg.starts_with("deadline:") {
                out = out.set("deadline_missed", true);
            }
            out.set("error", msg)
        }
    }
}

/// Server tuning knobs beyond the listen address (DESIGN.md §3,
/// docs/protocol.md §Protocol v2).
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// Size of the connection-handler pool (blocked mostly on socket
    /// I/O and coordinator replies) — distinct from the coordinator's
    /// `--workers` executor replicas and the `--threads` GEMM pool.
    pub conn_threads: usize,
    /// Per-connection credit window for protocol v2: the number of
    /// generations one connection may hold in flight before further
    /// `request` frames are rejected with a typed `overloaded:` error
    /// (`--conn-inflight`).
    pub conn_inflight: usize,
    /// v2 idle-connection reaper: after this long with no inbound
    /// frames and nothing in flight, the server pings; an unanswered
    /// ping closes the connection. `Duration::ZERO` disables reaping.
    pub idle_timeout: Duration,
    /// Decode cap on a single v2 frame's declared payload length.
    pub max_frame: usize,
    /// Refuse v1 JSON-lines connections (`serve --v2`): any first byte
    /// other than the `SMC2` magic gets an error line and a close.
    pub v2_only: bool,
}

impl Default for ServerOpts {
    fn default() -> ServerOpts {
        ServerOpts {
            conn_threads: 4,
            conn_inflight: 32,
            idle_timeout: Duration::from_secs(60),
            max_frame: frame::MAX_FRAME_LEN,
            v2_only: false,
        }
    }
}

/// A running TCP server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve with default v2 options. `addr` like
    /// "127.0.0.1:0" (0 = ephemeral port); `conn_threads` as in
    /// [`ServerOpts::conn_threads`].
    pub fn start(addr: &str, coord: Arc<Coordinator>, conn_threads: usize) -> Result<Server> {
        Server::start_with(addr, coord, ServerOpts { conn_threads, ..ServerOpts::default() })
    }

    /// Bind and serve with explicit [`ServerOpts`].
    pub fn start_with(addr: &str, coord: Arc<Coordinator>, opts: ServerOpts) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("smoothcache-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(opts.conn_threads.max(1));
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let coord = Arc::clone(&coord);
                            let stop3 = Arc::clone(&stop2);
                            pool.execute(move || {
                                let _ = handle_conn(stream, &coord, &stop3, opts);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// One non-blocking-ish poll of the request socket.
enum Polled {
    /// A complete line arrived.
    Line(String),
    /// The peer closed the connection.
    Closed,
    /// Nothing new within the read timeout.
    Idle,
}

/// Read one line with the stream's read timeout. `buf` persists across
/// calls so a line split over multiple reads (timeouts mid-line) is
/// reassembled instead of dropped.
fn poll_line(reader: &mut BufReader<TcpStream>, buf: &mut String) -> Result<Polled> {
    match reader.read_line(buf) {
        Ok(0) => Ok(Polled::Closed),
        Ok(_) => {
            let line = buf.trim().to_string();
            buf.clear();
            Ok(Polled::Line(line))
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            Ok(Polled::Idle)
        }
        Err(e) => Err(e.into()),
    }
}

fn write_line(writer: &mut TcpStream, line: &str) -> Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

/// One streaming `step` event line (shared by the in-loop emitter and
/// the post-reply drain so the two can never diverge on fields).
fn step_event(id: u64, p: &Progress) -> Json {
    let mut ev = Json::obj()
        .set("event", "step")
        .set("id", id)
        .set("step", p.step)
        .set("steps", p.steps)
        .set("computes", p.computes)
        .set("reuses", p.reuses)
        .set("t_s", p.elapsed_s);
    if let Some(d) = p.drift {
        ev = ev.set("drift", d);
    }
    ev
}

/// Drive one generation to completion, writing streaming events when
/// requested and watching the socket the whole time: a closed peer (or
/// an in-band `{"cmd":"cancel"}` line) cancels the in-flight request at
/// the coordinator, so abandoned work stops at the next solver step
/// instead of running to completion for nobody. Pipelined non-cancel
/// lines read while waiting are pushed onto `pending` and processed
/// after this request's final reply, preserving reply order.
///
/// EOF on the request stream is the departure signal: the protocol
/// requires clients to keep the write side open until the final reply
/// (docs/protocol.md §Cancellation) — a TCP half-close mid-generation
/// is indistinguishable from a vanished client, and shedding abandoned
/// work is the point of this surface. Returns `false` when the peer is
/// gone (the caller must drop the connection, including any pipelined
/// lines, without submitting them).
///
/// While a generation is in flight the socket read timeout is dropped
/// from the idle-loop [`IDLE_POLL_MS`] to [`GEN_POLL_MS`], so the wait
/// loop — reply recv + socket poll — adds at most a few tens of
/// milliseconds to the reply and drains step events at per-step
/// cadence instead of ~5 Hz bursts; the idle timeout is restored on
/// every exit path.
#[allow(clippy::too_many_arguments)]
fn run_generation(
    coord: &Coordinator,
    request: Request,
    opts: WireOpts,
    trace: TraceHandle,
    reader: &mut BufReader<TcpStream>,
    read_buf: &mut String,
    writer: &mut TcpStream,
    pending: &mut VecDeque<String>,
) -> Result<bool> {
    let _ = reader
        .get_ref()
        .set_read_timeout(Some(Duration::from_millis(GEN_POLL_MS)));
    let out =
        run_generation_inner(coord, request, opts, trace, reader, read_buf, writer, pending);
    let _ = reader
        .get_ref()
        .set_read_timeout(Some(Duration::from_millis(IDLE_POLL_MS)));
    out
}

/// Socket read timeout between requests (bounds how often an idle
/// connection handler re-checks the server stop flag).
const IDLE_POLL_MS: u64 = 200;
/// Socket read timeout and reply-poll interval while a generation is in
/// flight: bounds added reply latency, step-event flush cadence and
/// disconnect-detection time to ~2× this value.
const GEN_POLL_MS: u64 = 10;

#[allow(clippy::too_many_arguments)]
fn run_generation_inner(
    coord: &Coordinator,
    request: Request,
    opts: WireOpts,
    trace: TraceHandle,
    reader: &mut BufReader<TcpStream>,
    read_buf: &mut String,
    writer: &mut TcpStream,
    pending: &mut VecDeque<String>,
) -> Result<bool> {
    let (progress, progress_rx): (Option<_>, Option<Receiver<Progress>>) = if opts.stream {
        let (tx, rx) = channel();
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };
    let ticket = coord.submit_opts(
        request,
        SubmitOpts { progress, deadline: opts.deadline(), trace: trace.clone() },
    );
    let id = ticket.id;
    if opts.stream {
        // streaming clients learn the id up front so a sibling
        // connection (or this one, in-band) can cancel it
        let accepted = Json::obj().set("event", "accepted").set("ok", true).set("id", id);
        if write_line(writer, &accepted.to_string()).is_err() {
            coord.cancel(id);
            return Ok(false);
        }
    }
    let result = loop {
        if let Some(rx) = &progress_rx {
            while let Ok(p) = rx.try_recv() {
                if write_line(writer, &step_event(id, &p).to_string()).is_err() {
                    // client gone mid-stream
                    coord.cancel(id);
                    return Ok(false);
                }
            }
        }
        match ticket.reply.recv_timeout(Duration::from_millis(GEN_POLL_MS)) {
            Ok(r) => break r,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                break Err(crate::err!("coordinator shut down"))
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => match poll_line(reader, read_buf) {
                Ok(Polled::Idle) => {}
                Ok(Polled::Closed) => {
                    // cancel-on-disconnect: nobody is left to read the
                    // result, stop the work at the next step boundary
                    coord.cancel(id);
                    return Ok(false);
                }
                Ok(Polled::Line(l)) => {
                    if l.is_empty() {
                        continue;
                    }
                    // in-band cancel commands act immediately (their
                    // ack interleaves with step events; the final
                    // generation reply still arrives). Anything else
                    // waits its turn behind this generation.
                    match parse(&l) {
                        Ok(j) if j.get("cmd").and_then(|v| v.as_str()) == Some("cancel") => {
                            let reply = handle_control(coord, &j, &AtomicBool::new(false))
                                .expect("cancel is a control command");
                            if write_line(writer, &reply).is_err() {
                                coord.cancel(id);
                                return Ok(false);
                            }
                        }
                        _ => pending.push_back(l),
                    }
                }
                Err(e) => {
                    coord.cancel(id);
                    return Err(e);
                }
            },
        }
    };
    // drain any step events that raced the final reply
    if let Some(rx) = &progress_rx {
        while let Ok(p) = rx.try_recv() {
            if write_line(writer, &step_event(id, &p).to_string()).is_err() {
                coord.cancel(id); // no-op if already answered
                return Ok(false);
            }
        }
    }
    let ok = result.is_ok();
    let mut out = render_result_json(result, opts);
    if trace.is_active() {
        // the egress event lands in the wire timeline but not in the
        // flight-recorder entry, which the terminal reply path already
        // sealed (docs/adr/009)
        trace.event("send", out.to_string().len() as u64, 0, 0, f64::NAN);
        if let Some(t) = trace.snapshot() {
            out = out.set("trace", t.to_json());
        }
        // catch-all for paths that never reached a terminal finish
        // (e.g. coordinator shutdown mid-flight); idempotent otherwise
        trace.finish(if ok { Outcome::Ok } else { Outcome::Failed });
    }
    write_line(writer, &out.to_string())?;
    Ok(true)
}

/// Read one byte with the stream's read timeout, re-polling on timeout
/// until the stop flag is raised. `Ok(None)` means EOF or shutdown.
fn poll_byte(stream: &mut TcpStream, stop: &AtomicBool) -> Result<Option<u8>> {
    let mut one = [0u8; 1];
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut one) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(one[0])),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Protocol dispatch: sniff the connection's first byte. `S` (the start
/// of the `SMC2` magic — v1 lines always open with `{` or whitespace)
/// routes to the v2 mux handler; anything else replays the byte into
/// the v1 JSON-lines loop. With [`ServerOpts::v2_only`] the v1 path is
/// refused with a typed error line instead.
fn handle_conn(
    mut stream: TcpStream,
    coord: &Arc<Coordinator>,
    stop: &AtomicBool,
    opts: ServerOpts,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(IDLE_POLL_MS)))?;
    let Some(first) = poll_byte(&mut stream, stop)? else {
        return Ok(()); // closed or shutting down before any bytes
    };
    if first == frame::MAGIC[0] {
        // complete the magic before committing to v2
        let mut rest = [0u8; 3];
        for slot in rest.iter_mut() {
            match poll_byte(&mut stream, stop)? {
                Some(b) => *slot = b,
                None => return Ok(()),
            }
        }
        if rest != [frame::MAGIC[1], frame::MAGIC[2], frame::MAGIC[3]] {
            let _ = write_line(&mut stream, &fail("bad magic: expected SMC2 preamble".into()));
            return Ok(());
        }
        return mux::handle_conn_v2(stream, Arc::clone(coord), stop, opts);
    }
    if opts.v2_only {
        let _ = write_line(
            &mut stream,
            &fail("this server is v2-only: open with the SMC2 preamble".into()),
        );
        return Ok(());
    }
    if !first.is_ascii() {
        let _ = write_line(&mut stream, &fail("bad json: not a JSON-lines stream".into()));
        return Ok(());
    }
    handle_conn_v1(stream, coord, stop, first as char)
}

/// The v1 JSON-lines connection loop. `first` is the already-sniffed
/// first byte of the stream, replayed at the front of the line buffer.
fn handle_conn_v1(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
    first: char,
) -> Result<()> {
    // Periodic read timeouts let the handler observe the stop flag even
    // while a client holds an idle connection open (otherwise server
    // shutdown would deadlock joining this thread) — and, during a
    // generation, let run_generation watch for disconnects (it tightens
    // the timeout to GEN_POLL_MS for that window).
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut read_buf = String::new();
    // read_line appends, so the sniffed byte stays at the line's front
    if first != '\n' {
        read_buf.push(first);
    }
    let mut pending: VecDeque<String> = VecDeque::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let line = match pending.pop_front() {
            Some(l) => l,
            None => match poll_line(&mut reader, &mut read_buf)? {
                Polled::Closed => return Ok(()), // client closed
                Polled::Idle => continue,
                Polled::Line(l) => l,
            },
        };
        if line.is_empty() {
            continue;
        }
        let j = match parse(&line) {
            Ok(j) => j,
            Err(e) => {
                write_line(&mut writer, &fail(format!("bad json: {e}")))?;
                continue;
            }
        };
        if let Some(reply) = handle_control(coord, &j, stop) {
            write_line(&mut writer, &reply)?;
        } else {
            match parse_request(&j) {
                Ok((request, opts)) => {
                    // open a wire-visible trace only on request; the
                    // coordinator still auto-traces for the flight
                    // recorder when this stays off (docs/adr/009)
                    let trace =
                        if opts.trace { TraceHandle::start() } else { TraceHandle::off() };
                    trace.event("recv", line.len() as u64, 0, 0, f64::NAN);
                    let alive = run_generation(
                        coord,
                        request,
                        opts,
                        trace,
                        &mut reader,
                        &mut read_buf,
                        &mut writer,
                        &mut pending,
                    )?;
                    if !alive {
                        // peer gone: drop the connection and any
                        // pipelined lines instead of submitting work
                        // for nobody
                        return Ok(());
                    }
                }
                Err(e) => write_line(&mut writer, &fail(format!("{e}")))?,
            }
        }
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Default connect/read/write timeout for both [`Client`] and
/// [`Client2`]: generous enough for a cold-cache generation reply, but
/// a dead server produces a typed `timeout:` error instead of a hang.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Minimal blocking client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    io_timeout: Option<Duration>,
}

impl Client {
    /// Connect with [`DEFAULT_IO_TIMEOUT`] for connect, read and write.
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        Client::connect_with(addr, DEFAULT_IO_TIMEOUT)
    }

    /// Connect with an explicit timeout applied to the TCP connect and
    /// installed as both the read and write timeout.
    pub fn connect_with(addr: &std::net::SocketAddr, io_timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(addr, io_timeout)
            .map_err(|e| crate::err!("timeout: connect {addr}: {e}"))?;
        let mut c = Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            io_timeout: None,
        };
        c.set_read_timeout(Some(io_timeout))?;
        c.set_write_timeout(Some(io_timeout))?;
        Ok(c)
    }

    /// Bound how long [`Client::call`]/`read_reply` wait for a reply
    /// line; `None` blocks forever (pre-timeout behavior).
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(t)?;
        self.io_timeout = t;
        Ok(())
    }

    /// Bound how long request writes may block on a full send buffer.
    pub fn set_write_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.writer.set_write_timeout(t)?;
        Ok(())
    }

    /// Send one JSON value, read one JSON reply.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        self.read_reply()
    }

    fn read_reply(&mut self) -> Result<Json> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => return Err(crate::err!("connection closed by server")),
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(crate::err!(
                    "timeout: no reply within {:?}",
                    self.io_timeout.unwrap_or(DEFAULT_IO_TIMEOUT)
                ));
            }
            Err(e) => return Err(e.into()),
        }
        parse(line.trim()).map_err(|e| crate::err!("bad reply: {e} ({line:?})"))
    }

    /// Send a generation request in streaming mode (`stream: true` is
    /// added to `req`), invoking `on_event` for every `accepted` /
    /// `step` event line, and returning the final result line.
    pub fn call_streaming(
        &mut self,
        req: &Json,
        mut on_event: impl FnMut(&Json),
    ) -> Result<Json> {
        let req = req.clone().set("stream", true);
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        loop {
            let j = self.read_reply()?;
            match j.get("event").and_then(|v| v.as_str()) {
                Some("accepted") | Some("step") => on_event(&j),
                _ => return Ok(j), // the final result line
            }
        }
    }

    /// Cancel an in-flight request by id (`{"cmd":"cancel","id":N}`).
    /// Returns whether the server still knew the id.
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        let r = self.call(&Json::obj().set("cmd", "cancel").set("id", id))?;
        Ok(r.get("cancelled").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj().set("cmd", "ping"))?;
        Ok(r.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    pub fn metrics_summary(&mut self) -> Result<String> {
        let r = self.call(&Json::obj().set("cmd", "metrics"))?;
        Ok(r.get("summary").and_then(|v| v.as_str()).unwrap_or("").to_string())
    }

    /// Structured metrics (`{"cmd":"metrics","format":"json"}`) —
    /// returns the `"metrics"` object (docs/protocol.md).
    pub fn metrics_json(&mut self) -> Result<Json> {
        let r = self.call(&Json::obj().set("cmd", "metrics").set("format", "json"))?;
        r.get("metrics")
            .cloned()
            .ok_or_else(|| crate::err!("metrics reply missing \"metrics\" object"))
    }

    /// Dump the server's flight recorder (`{"cmd":"dump"}`): the full
    /// reply carries `"level"` and `"entries"` (docs/adr/009).
    pub fn dump(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "dump"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_image() {
        let j = parse(
            r#"{"family":"image","label":3,"steps":12,"solver":"ddim",
                "cfg":1.5,"seed":9,"policy":"smooth:0.18"}"#,
        )
        .unwrap();
        let (r, opts) = parse_request(&j).unwrap();
        assert_eq!(r.family, "image");
        assert_eq!(r.cond, Cond::Label(vec![3]));
        assert_eq!(r.steps, 12);
        assert_eq!(r.cfg_scale, 1.5);
        assert_eq!(r.policy, Policy::smooth(0.18));
        assert_eq!(r.compute, ComputeMode::F32);
        assert!(!opts.return_latent);
        assert!(!opts.stream);
        assert_eq!(opts.deadline_ms, None);
    }

    #[test]
    fn parse_request_compute_field() {
        for (wire, mode) in [
            ("f32", ComputeMode::F32),
            ("f16", ComputeMode::F16),
            ("bf16", ComputeMode::Bf16),
            ("int8", ComputeMode::Int8),
        ] {
            let j = parse(&format!(
                r#"{{"family":"image","label":1,"compute":"{wire}"}}"#
            ))
            .unwrap();
            assert_eq!(parse_request(&j).unwrap().0.compute, mode);
        }
        // unknown names and non-string values are wire errors, not
        // silent f32 fallbacks
        for bad in [
            r#"{"family":"image","label":1,"compute":"fp8"}"#,
            r#"{"family":"image","label":1,"compute":16}"#,
        ] {
            let j = parse(bad).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert!(format!("{err}").contains("compute"), "{bad}: {err}");
        }
    }

    #[test]
    fn parse_request_prompt() {
        let j = parse(
            r#"{"family":"audio","prompt_ids":[1,2,3,4,5,6,7,8],
                "solver":"dpmpp3m-sde","policy":"fora:2","return_latent":true}"#,
        )
        .unwrap();
        let (r, opts) = parse_request(&j).unwrap();
        assert_eq!(r.cond, Cond::Prompt(vec![1, 2, 3, 4, 5, 6, 7, 8]));
        assert_eq!(r.solver, SolverKind::DpmPP3M { sde: true });
        assert!(opts.return_latent);
    }

    #[test]
    fn parse_request_seed_is_lossless_and_validated() {
        // the full exactly-representable range round-trips…
        let j = parse(r#"{"family":"image","label":1,"seed":9007199254740991}"#).unwrap();
        let (r, _) = parse_request(&j).unwrap();
        assert_eq!(r.seed, (1 << 53) - 1);
        // …absent seeds default to 0…
        let j = parse(r#"{"family":"image","label":1}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().0.seed, 0);
        // …and anything an `as u64` cast would have silently mangled is
        // a wire error instead: negatives, fractions, > 2^53, strings
        for bad in [
            r#"{"family":"image","label":1,"seed":-1}"#,
            r#"{"family":"image","label":1,"seed":1.5}"#,
            r#"{"family":"image","label":1,"seed":9007199254740993}"#,
            r#"{"family":"image","label":1,"seed":18446744073709551615}"#,
            r#"{"family":"image","label":1,"seed":"7"}"#,
        ] {
            let j = parse(bad).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert!(format!("{err}").contains("seed"), "{bad}: {err}");
        }
    }

    #[test]
    fn parse_request_stream_and_deadline_fields() {
        let j = parse(
            r#"{"family":"image","label":1,"stream":true,
                "deadline_ms":250,"deadline_policy":"reject"}"#,
        )
        .unwrap();
        let (_, opts) = parse_request(&j).unwrap();
        assert!(opts.stream);
        assert_eq!(opts.deadline_ms, Some(250));
        assert_eq!(opts.deadline_policy, DeadlinePolicy::RejectLate);
        assert!(opts.deadline().is_some());

        // defaults: best-effort, no deadline
        let j = parse(r#"{"family":"image","label":1,"deadline_ms":10}"#).unwrap();
        let (_, opts) = parse_request(&j).unwrap();
        assert_eq!(opts.deadline_policy, DeadlinePolicy::BestEffort);

        // malformed values are wire errors
        for bad in [
            r#"{"family":"image","label":1,"deadline_ms":0}"#,
            r#"{"family":"image","label":1,"deadline_ms":-5}"#,
            r#"{"family":"image","label":1,"deadline_ms":1.5}"#,
            r#"{"family":"image","label":1,"deadline_policy":"strict"}"#,
        ] {
            let j = parse(bad).unwrap();
            assert!(parse_request(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_request_rejects_malformed_policy_parameters() {
        // wire input with invalid parameters must fail at parse time
        // (it used to reach — and panic — an executor replica)
        for policy in ["fora:0", "smooth:NaN", "smooth:inf", "delta-dit:0", "drift:0"] {
            let j = parse(&format!(
                r#"{{"family":"image","label":1,"policy":"{policy}"}}"#
            ))
            .unwrap();
            assert!(parse_request(&j).is_err(), "{policy} should be rejected");
        }
        // the dynamic drift policy is a first-class wire policy
        let j = parse(r#"{"family":"image","label":1,"policy":"drift:0.3"}"#).unwrap();
        let (r, _) = parse_request(&j).unwrap();
        assert_eq!(r.policy.wire(), "drift:0.3");
    }

    #[test]
    fn parse_request_priority_field() {
        // absent → interactive (existing clients are unaffected)
        let j = parse(r#"{"family":"image","label":1}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().0.priority, PriorityClass::Interactive);
        let j = parse(r#"{"family":"image","label":1,"priority":"interactive"}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().0.priority, PriorityClass::Interactive);
        let j = parse(r#"{"family":"image","label":1,"priority":"batch"}"#).unwrap();
        assert_eq!(parse_request(&j).unwrap().0.priority, PriorityClass::Batch);
        // unknown names and non-string values are wire errors, not
        // silent interactive fallbacks
        for bad in [
            r#"{"family":"image","label":1,"priority":"urgent"}"#,
            r#"{"family":"image","label":1,"priority":1}"#,
        ] {
            let j = parse(bad).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert!(format!("{err}").contains("priority"), "{bad}: {err}");
        }
    }

    #[test]
    fn parse_request_rejects_missing_cond() {
        let j = parse(r#"{"family":"image"}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }

    #[test]
    fn parse_request_rejects_mixed_prompt_ids() {
        // regression: as_f64_vec used to filter_map mixed arrays down
        // to their numeric elements, silently shortening the prompt
        for bad in [
            r#"{"family":"audio","prompt_ids":[1,"x",3]}"#,
            r#"{"family":"audio","prompt_ids":[1,null]}"#,
            r#"{"family":"audio","prompt_ids":"1 2 3"}"#,
        ] {
            let j = parse(bad).unwrap();
            let err = parse_request(&j).unwrap_err();
            assert!(format!("{err}").contains("prompt_ids"), "{bad}: {err}");
        }
    }

    #[test]
    fn parse_request_rejects_bad_solver() {
        let j = parse(r#"{"family":"image","label":0,"solver":"magic"}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }

    #[test]
    fn render_result_flags_error_classes() {
        let opts = WireOpts::default();
        for (msg, flag) in [
            ("overloaded: queue full", "overloaded"),
            ("cancelled: request 3 was cancelled", "cancelled"),
            ("deadline: request 3 exceeded its deadline", "deadline_missed"),
        ] {
            let line = render_result_json(Err(crate::err!("{msg}")), opts).to_string();
            let j = parse(&line).unwrap();
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{line}");
            assert_eq!(j.get(flag).and_then(|v| v.as_bool()), Some(true), "{line}");
        }
        // plain failures carry no class flag
        let line = render_result_json(Err(crate::err!("boom")), opts).to_string();
        let j = parse(&line).unwrap();
        assert!(j.get("overloaded").is_none() && j.get("cancelled").is_none());
    }
}
