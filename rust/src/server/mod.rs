//! TCP JSON-lines serving front-end + client library.
//!
//! One JSON object per line in each direction. Request fields:
//! `family`, `steps`, `solver`, `policy`, `cfg`, `seed`, and either
//! `label` (image) or `prompt_ids` (audio/video); `return_latent`
//! includes the generated latent in the response. Control commands:
//! `{"cmd": "ping"}`, `{"cmd": "metrics"}`, `{"cmd": "shutdown"}`.
//! Failures are answered in-line as `{"ok": false, "error": "…"}`;
//! admission-control rejections (the coordinator's work queue at
//! `--queue-depth`, see [`crate::coordinator::queue`]) additionally
//! carry `"overloaded": true` so clients can back off and retry
//! rather than treating the reply as a permanent failure.
//!
//! The full wire contract (field semantics, defaults, batching
//! guarantees, error + overload shapes, metrics-summary fields) is
//! specified in `docs/protocol.md` at the repository root — keep the
//! two in sync when evolving the protocol. The `policy` vocabulary is
//! the registry in [`crate::cache::plan::registry`]: the doc's policy
//! table is generated from it (and pinned by a test), so adding a
//! policy there is all a new wire value needs.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::error::{Context, Result};

use crate::coordinator::{Coordinator, Policy, Request};
use crate::model::Cond;
use crate::solvers::SolverKind;
use crate::util::json::{parse, Json};
use crate::util::threadpool::ThreadPool;

/// Parse one request line into a coordinator [`Request`].
pub fn parse_request(j: &Json) -> Result<(Request, bool)> {
    let family = j
        .get("family")
        .and_then(|v| v.as_str())
        .ok_or_else(|| crate::err!("missing family"))?
        .to_string();
    let steps = j.get("steps").and_then(|v| v.as_usize()).unwrap_or(50);
    let solver_name = j.get("solver").and_then(|v| v.as_str()).unwrap_or("ddim");
    let solver =
        SolverKind::parse(solver_name).ok_or_else(|| crate::err!("unknown solver {solver_name}"))?;
    let policy_s = j.get("policy").and_then(|v| v.as_str()).unwrap_or("no-cache");
    let policy = Policy::parse(policy_s)?;
    let cfg_scale = j.get("cfg").and_then(|v| v.as_f64()).unwrap_or(1.0) as f32;
    let seed = j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let cond = if let Some(l) = j.get("label").and_then(|v| v.as_f64()) {
        Cond::Label(vec![l as i32])
    } else if let Some(p) = j.get("prompt_ids").and_then(|v| v.as_f64_vec()) {
        Cond::Prompt(p.into_iter().map(|x| x as i32).collect())
    } else {
        return Err(crate::err!("need label or prompt_ids"));
    };
    let return_latent = j.get("return_latent").and_then(|v| v.as_bool()).unwrap_or(false);
    Ok((
        Request { id: 0, family, cond, solver, steps, cfg_scale, seed, policy },
        return_latent,
    ))
}

fn handle_line(coord: &Coordinator, line: &str, stop: &AtomicBool) -> String {
    let fail = |msg: String| Json::obj().set("ok", false).set("error", msg).to_string();
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return fail(format!("bad json: {e}")),
    };
    if let Some(cmd) = j.get("cmd").and_then(|v| v.as_str()) {
        return match cmd {
            "ping" => Json::obj().set("ok", true).set("pong", true).to_string(),
            "metrics" => Json::obj()
                .set("ok", true)
                .set("summary", coord.metrics().summary())
                .to_string(),
            "shutdown" => {
                stop.store(true, Ordering::SeqCst);
                Json::obj().set("ok", true).set("stopping", true).to_string()
            }
            other => fail(format!("unknown cmd {other}")),
        };
    }
    let (request, return_latent) = match parse_request(&j) {
        Ok(r) => r,
        Err(e) => return fail(format!("{e}")),
    };
    match coord.generate_blocking(request) {
        Ok(resp) => {
            let mut out = Json::obj()
                .set("ok", true)
                .set("id", resp.id)
                .set(
                    "latent_shape",
                    resp.latent.shape.iter().map(|&d| Json::Num(d as f64)).collect::<Vec<_>>(),
                )
                .set("batch_size", resp.batch_size)
                .set("queue_s", resp.queue_seconds)
                .set("exec_s", resp.exec_seconds)
                .set("total_s", resp.total_seconds)
                .set("skip_fraction", resp.gen_stats.skip_fraction());
            if return_latent {
                out = out.set(
                    "latent",
                    resp.latent.data.iter().map(|&v| Json::Num(v as f64)).collect::<Vec<_>>(),
                );
            }
            out.to_string()
        }
        Err(e) => {
            let msg = format!("{e}");
            if msg.starts_with("overloaded:") {
                // queue-admission rejection: mark it machine-readably so
                // clients know to back off and retry (docs/protocol.md)
                return Json::obj()
                    .set("ok", false)
                    .set("overloaded", true)
                    .set("error", msg)
                    .to_string();
            }
            fail(msg)
        }
    }
}

/// A running TCP server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve. `addr` like "127.0.0.1:0" (0 = ephemeral port).
    ///
    /// `conn_threads` sizes the *connection-handler* pool (blocked
    /// mostly on socket I/O and coordinator replies) — distinct from
    /// the coordinator's `--workers` executor replicas and the
    /// `--threads` GEMM compute pool (see DESIGN.md §3).
    pub fn start(addr: &str, coord: Arc<Coordinator>, conn_threads: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("smoothcache-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(conn_threads.max(1));
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let coord = Arc::clone(&coord);
                            let stop3 = Arc::clone(&stop2);
                            pool.execute(move || {
                                let _ = handle_conn(stream, &coord, &stop3);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coord: &Coordinator, stop: &AtomicBool) -> Result<()> {
    // Periodic read timeouts let the handler observe the stop flag even
    // while a client holds an idle connection open (otherwise server
    // shutdown would deadlock joining this thread).
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = handle_line(coord, trimmed, stop);
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Minimal blocking client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one JSON value, read one JSON reply.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(line.trim()).map_err(|e| crate::err!("bad reply: {e} ({line:?})"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj().set("cmd", "ping"))?;
        Ok(r.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    pub fn metrics_summary(&mut self) -> Result<String> {
        let r = self.call(&Json::obj().set("cmd", "metrics"))?;
        Ok(r.get("summary").and_then(|v| v.as_str()).unwrap_or("").to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_image() {
        let j = parse(
            r#"{"family":"image","label":3,"steps":12,"solver":"ddim",
                "cfg":1.5,"seed":9,"policy":"smooth:0.18"}"#,
        )
        .unwrap();
        let (r, ret) = parse_request(&j).unwrap();
        assert_eq!(r.family, "image");
        assert_eq!(r.cond, Cond::Label(vec![3]));
        assert_eq!(r.steps, 12);
        assert_eq!(r.cfg_scale, 1.5);
        assert_eq!(r.policy, Policy::smooth(0.18));
        assert!(!ret);
    }

    #[test]
    fn parse_request_prompt() {
        let j = parse(
            r#"{"family":"audio","prompt_ids":[1,2,3,4,5,6,7,8],
                "solver":"dpmpp3m-sde","policy":"fora:2","return_latent":true}"#,
        )
        .unwrap();
        let (r, ret) = parse_request(&j).unwrap();
        assert_eq!(r.cond, Cond::Prompt(vec![1, 2, 3, 4, 5, 6, 7, 8]));
        assert_eq!(r.solver, SolverKind::DpmPP3M { sde: true });
        assert!(ret);
    }

    #[test]
    fn parse_request_rejects_malformed_policy_parameters() {
        // wire input with invalid parameters must fail at parse time
        // (it used to reach — and panic — an executor replica)
        for policy in ["fora:0", "smooth:NaN", "smooth:inf", "delta-dit:0", "drift:0"] {
            let j = parse(&format!(
                r#"{{"family":"image","label":1,"policy":"{policy}"}}"#
            ))
            .unwrap();
            assert!(parse_request(&j).is_err(), "{policy} should be rejected");
        }
        // the dynamic drift policy is a first-class wire policy
        let j = parse(r#"{"family":"image","label":1,"policy":"drift:0.3"}"#).unwrap();
        let (r, _) = parse_request(&j).unwrap();
        assert_eq!(r.policy.wire(), "drift:0.3");
    }

    #[test]
    fn parse_request_rejects_missing_cond() {
        let j = parse(r#"{"family":"image"}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }

    #[test]
    fn parse_request_rejects_bad_solver() {
        let j = parse(r#"{"family":"image","label":0,"solver":"magic"}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }
}
