//! The denoising pipeline: solver loop × forward engine × SmoothCache.
//!
//! This is where the paper's mechanism executes: at every solver step
//! the pipeline walks the (block, branch) sites in order; a `Compute`
//! decision runs the branch's AOT executable and refills the layer
//! cache, a `Reuse` decision re-injects the cached delta through the
//! residual connection without touching PJRT (paper Fig. 3). Decisions
//! come from a static [`Schedule`] (grouped by branch type, the paper's
//! default) or a per-site decision map (grouping ablation).

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use crate::util::error::Result;

use crate::cache::schedule::{Decision, Schedule};
use crate::model::{Cond, Engine};
use crate::solvers::{cfg_merge, SolverKind, SolverRun};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One generation request's sampling configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub family: String,
    pub solver: SolverKind,
    pub steps: usize,
    /// classifier-free guidance scale; 1.0 disables CFG (single forward).
    pub cfg_scale: f32,
    pub seed: u64,
}

impl GenConfig {
    pub fn new(family: &str, solver: SolverKind, steps: usize) -> GenConfig {
        GenConfig { family: family.into(), solver, steps, cfg_scale: 1.0, seed: 0 }
    }

    pub fn with_cfg(mut self, scale: f32) -> GenConfig {
        self.cfg_scale = scale;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> GenConfig {
        self.seed = seed;
        self
    }

    pub fn uses_cfg(&self) -> bool {
        (self.cfg_scale - 1.0).abs() > 1e-6
    }
}

/// Caching policy for one generation.
pub enum CacheMode<'a> {
    /// compute everything (No-Cache rows; calibration).
    None,
    /// the paper's grouped-by-type static schedule.
    Grouped(&'a Schedule),
    /// per-(block, branch) decisions — grouping ablation.
    PerSite(&'a BTreeMap<String, Vec<Decision>>),
}

#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub branch_computes: usize,
    pub branch_reuses: usize,
    pub steps: usize,
    pub wall_seconds: f64,
}

impl GenStats {
    pub fn skip_fraction(&self) -> f64 {
        let total = self.branch_computes + self.branch_reuses;
        if total == 0 {
            0.0
        } else {
            self.branch_reuses as f64 / total as f64
        }
    }
}

pub struct GenOutput {
    /// `[batch, …latent_shape]` generated latents at t = 0.
    pub latent: Tensor,
    pub stats: GenStats,
}

/// Observer over *computed* branch deltas: (step, block, branch, delta).
pub type DeltaObserver<'a> = &'a mut dyn FnMut(usize, usize, &str, &Tensor);

/// Run one full denoising trajectory; the initial latent is drawn from
/// `cfg.seed`.
pub fn generate(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    mode: &CacheMode,
    observer: Option<DeltaObserver>,
) -> Result<GenOutput> {
    let fm = engine.family_manifest(&cfg.family)?.clone();
    let batch = cond.batch(fm.cond_len);
    if batch == 0 {
        return Err(crate::err!("empty batch"));
    }
    let mut rng = Rng::new(cfg.seed);
    let mut latent_shape = vec![batch];
    latent_shape.extend(&fm.latent_shape);
    let x0 = SolverRun::init_latent(latent_shape, &mut rng);
    generate_from(engine, cfg, cond, x0, mode, observer)
}

/// Like [`generate`] but with a caller-provided initial latent — the
/// dynamic batcher uses this so each request's trajectory is seeded from
/// its own seed regardless of batch composition.
pub fn generate_from(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    x_init: Tensor,
    mode: &CacheMode,
    mut observer: Option<DeltaObserver>,
) -> Result<GenOutput> {
    let t_start = Instant::now();
    let fm = engine.family_manifest(&cfg.family)?.clone();
    let batch = cond.batch(fm.cond_len);
    if batch == 0 {
        return Err(crate::err!("empty batch"));
    }
    if x_init.dim0() != batch {
        return Err(crate::err!("x_init batch {} != cond batch {batch}", x_init.dim0()));
    }
    if let CacheMode::Grouped(s) = mode {
        if s.steps != cfg.steps {
            return Err(crate::err!("schedule has {} steps, request has {}", s.steps, cfg.steps));
        }
        if s.branch_types != fm.branch_types {
            return Err(crate::err!("schedule branch types do not match family"));
        }
    }

    let mut rng = Rng::new(cfg.seed ^ 0x50D4_11CE);
    let mut run = SolverRun::new(cfg.solver, cfg.steps);
    let mut x = x_init;

    // CFG: the conditional and null batches run concatenated.
    let cond_eff = if cfg.uses_cfg() {
        cond.cat(&cond.null_like(fm.num_classes, fm.cond_len))
    } else {
        cond.clone()
    };
    let batch_eff = if cfg.uses_cfg() { 2 * batch } else { batch };

    let sites = fm.branch_sites();
    let mut cache: HashMap<(usize, String), Tensor> = HashMap::new();
    let mut stats = GenStats { steps: cfg.steps, ..Default::default() };

    for i in 0..cfg.steps {
        let t = run.model_t(i) as f32;
        let x_in = if cfg.uses_cfg() { Tensor::cat0(&[&x, &x]) } else { x.clone() };
        let t_vec = vec![t; batch_eff];
        let emb = engine.embed(&cfg.family, &x_in, &t_vec, &cond_eff)?;
        let ctx = engine.make_step_ctx(&emb)?;
        let mut tokens = emb.tokens;

        for (block, br) in &sites {
            let decision = match mode {
                CacheMode::None => Decision::Compute,
                CacheMode::Grouped(s) => s.decision(i, br),
                CacheMode::PerSite(m) => m
                    .get(&format!("{block}.{br}"))
                    .map(|ds| ds[i])
                    .unwrap_or(Decision::Compute),
            };
            let key = (*block, br.clone());
            let delta = match decision {
                Decision::Compute => {
                    let d = engine.branch(&cfg.family, *block, br, &tokens, &ctx)?;
                    if let Some(obs) = observer.as_deref_mut() {
                        obs(i, *block, br, &d);
                    }
                    stats.branch_computes += 1;
                    cache.insert(key, d.clone());
                    d
                }
                Decision::Reuse { .. } => {
                    stats.branch_reuses += 1;
                    cache
                        .get(&key)
                        .cloned()
                        .ok_or_else(|| crate::err!("cache miss at step {i} {block}.{br}"))?
                }
            };
            tokens.add_inplace(&delta);
        }

        let out = engine.final_head(&cfg.family, &tokens, &ctx)?;
        let model_out = if cfg.uses_cfg() {
            let c = out.batch_slice(0, batch);
            let u = out.batch_slice(batch, 2 * batch);
            cfg_merge(&c, &u, cfg.cfg_scale)
        } else {
            out
        };
        x = run.step(i, &x, &model_out, &mut rng);
    }

    stats.wall_seconds = t_start.elapsed().as_secs_f64();
    Ok(GenOutput { latent: x, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_config_cfg_detection() {
        let c = GenConfig::new("image", SolverKind::Ddim, 10);
        assert!(!c.uses_cfg());
        assert!(c.with_cfg(1.5).uses_cfg());
    }

    #[test]
    fn stats_skip_fraction() {
        let s = GenStats { branch_computes: 30, branch_reuses: 10, ..Default::default() };
        assert!((s.skip_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(GenStats::default().skip_fraction(), 0.0);
    }
}
