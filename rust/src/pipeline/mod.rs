//! The denoising pipeline: solver loop × forward engine × SmoothCache.
//!
//! This is where the paper's mechanism executes: at every solver step
//! the pipeline walks the (block, branch) sites in order; a `Compute`
//! decision runs the branch's AOT executable and refills the layer
//! cache, a `Reuse` decision re-injects the cached delta through the
//! residual connection without touching the backend (paper Fig. 3).
//! Decisions come from one [`PlanRef`]: a dense
//! [`crate::cache::CachePlan`] (static policies; the inner loop's
//! scheduling cost is a single flat-array read per site — no string
//! keys, no map lookups) or a
//! [`crate::cache::StepPlanner`] deciding at runtime from per-site
//! observations (cache age, last observed delta drift).

use std::time::Instant;

use crate::util::error::Result;

use crate::cache::plan::{PlanRef, StepObs};
use crate::cache::schedule::Decision;
use crate::model::{Cond, Engine};
use crate::solvers::{cfg_merge, SolverKind, SolverRun};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One generation request's sampling configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub family: String,
    pub solver: SolverKind,
    pub steps: usize,
    /// classifier-free guidance scale; 1.0 disables CFG (single forward).
    pub cfg_scale: f32,
    pub seed: u64,
}

impl GenConfig {
    pub fn new(family: &str, solver: SolverKind, steps: usize) -> GenConfig {
        GenConfig { family: family.into(), solver, steps, cfg_scale: 1.0, seed: 0 }
    }

    pub fn with_cfg(mut self, scale: f32) -> GenConfig {
        self.cfg_scale = scale;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> GenConfig {
        self.seed = seed;
        self
    }

    pub fn uses_cfg(&self) -> bool {
        (self.cfg_scale - 1.0).abs() > 1e-6
    }
}

#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub branch_computes: usize,
    pub branch_reuses: usize,
    pub steps: usize,
    pub wall_seconds: f64,
}

impl GenStats {
    pub fn skip_fraction(&self) -> f64 {
        let total = self.branch_computes + self.branch_reuses;
        if total == 0 {
            0.0
        } else {
            self.branch_reuses as f64 / total as f64
        }
    }
}

pub struct GenOutput {
    /// `[batch, …latent_shape]` generated latents at t = 0.
    pub latent: Tensor,
    pub stats: GenStats,
}

/// Observer over *computed* branch deltas: (step, block, branch, delta).
pub type DeltaObserver<'a> = &'a mut dyn FnMut(usize, usize, &str, &Tensor);

/// Run one full denoising trajectory; the initial latent is drawn from
/// `cfg.seed`.
pub fn generate(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    plan: PlanRef<'_>,
    observer: Option<DeltaObserver>,
) -> Result<GenOutput> {
    let fm = engine.family_manifest(&cfg.family)?.clone();
    let batch = cond.batch(fm.cond_len);
    if batch == 0 {
        return Err(crate::err!("empty batch"));
    }
    let mut rng = Rng::new(cfg.seed);
    let mut latent_shape = vec![batch];
    latent_shape.extend(&fm.latent_shape);
    let x0 = SolverRun::init_latent(latent_shape, &mut rng);
    generate_from(engine, cfg, cond, x0, plan, observer)
}

/// Like [`generate`] but with a caller-provided initial latent — the
/// dynamic batcher uses this so each request's trajectory is seeded from
/// its own seed regardless of batch composition.
pub fn generate_from(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    x_init: Tensor,
    plan: PlanRef<'_>,
    mut observer: Option<DeltaObserver>,
) -> Result<GenOutput> {
    let t_start = Instant::now();
    let fm = engine.family_manifest(&cfg.family)?.clone();
    let batch = cond.batch(fm.cond_len);
    if batch == 0 {
        return Err(crate::err!("empty batch"));
    }
    if x_init.dim0() != batch {
        return Err(crate::err!("x_init batch {} != cond batch {batch}", x_init.dim0()));
    }
    // Static plans are checked against this exact configuration up
    // front: step count and the family's site enumeration must match —
    // a plan built for a different family fails loudly here instead of
    // silently computing at unmatched sites.
    if let PlanRef::Plan(p) = plan {
        p.validate_for(&fm, cfg.steps)?;
    }
    let dynamic = matches!(plan, PlanRef::Planner(_));

    let mut rng = Rng::new(cfg.seed ^ 0x50D4_11CE);
    let mut run = SolverRun::new(cfg.solver, cfg.steps);
    let mut x = x_init;

    // CFG: the conditional and null batches run concatenated.
    let cond_eff = if cfg.uses_cfg() {
        cond.cat(&cond.null_like(fm.num_classes, fm.cond_len))
    } else {
        cond.clone()
    };
    let batch_eff = if cfg.uses_cfg() { 2 * batch } else { batch };

    let sites = fm.branch_sites();
    let n_sites = sites.len();
    // per-site state, indexed by site position (no string keys):
    let mut cache: Vec<Option<Tensor>> = vec![None; n_sites];
    let mut filled_at: Vec<Option<usize>> = vec![None; n_sites];
    // drift feedback for dynamic planners: relative L1 error between a
    // freshly computed delta and the cached one it replaces. Only
    // tracked when a StepPlanner is driving — static plans skip the
    // extra tensor pass entirely.
    let mut last_drift: Vec<Option<f64>> = vec![None; n_sites];
    let mut stats = GenStats { steps: cfg.steps, ..Default::default() };

    for i in 0..cfg.steps {
        let t = run.model_t(i) as f32;
        let x_in = if cfg.uses_cfg() { Tensor::cat0(&[&x, &x]) } else { x.clone() };
        let t_vec = vec![t; batch_eff];
        let emb = engine.embed(&cfg.family, &x_in, &t_vec, &cond_eff)?;
        let ctx = engine.make_step_ctx(&emb)?;
        let mut tokens = emb.tokens;

        for (s_idx, (block, br)) in sites.iter().enumerate() {
            let decision = match plan {
                PlanRef::Plan(p) => p.decision(i, s_idx),
                PlanRef::Planner(sp) => {
                    let obs = StepObs {
                        filled_at: filled_at[s_idx],
                        last_drift: last_drift[s_idx],
                    };
                    sp.decide(i, s_idx, &obs)
                }
            };
            let delta = match decision {
                Decision::Compute => {
                    let d = engine.branch(&cfg.family, *block, br, &tokens, &ctx)?;
                    if let Some(obs) = observer.as_deref_mut() {
                        obs(i, *block, br, &d);
                    }
                    stats.branch_computes += 1;
                    if dynamic {
                        if let Some(old) = &cache[s_idx] {
                            last_drift[s_idx] = Some(d.rel_l1_error(old));
                        }
                    }
                    filled_at[s_idx] = Some(i);
                    cache[s_idx] = Some(d.clone());
                    d
                }
                Decision::Reuse { .. } => {
                    stats.branch_reuses += 1;
                    cache[s_idx].clone().ok_or_else(|| {
                        crate::err!(
                            "cache miss at step {i} site {block}.{br}: \
                             plan decided Reuse before any compute"
                        )
                    })?
                }
            };
            tokens.add_inplace(&delta);
        }

        let out = engine.final_head(&cfg.family, &tokens, &ctx)?;
        let model_out = if cfg.uses_cfg() {
            let c = out.batch_slice(0, batch);
            let u = out.batch_slice(batch, 2 * batch);
            cfg_merge(&c, &u, cfg.cfg_scale)
        } else {
            out
        };
        x = run.step(i, &x, &model_out, &mut rng);
    }

    stats.wall_seconds = t_start.elapsed().as_secs_f64();
    Ok(GenOutput { latent: x, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_config_cfg_detection() {
        let c = GenConfig::new("image", SolverKind::Ddim, 10);
        assert!(!c.uses_cfg());
        assert!(c.with_cfg(1.5).uses_cfg());
    }

    #[test]
    fn stats_skip_fraction() {
        let s = GenStats { branch_computes: 30, branch_reuses: 10, ..Default::default() };
        assert!((s.skip_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(GenStats::default().skip_fraction(), 0.0);
    }
}
