//! The denoising pipeline: solver loop × forward engine × SmoothCache.
//!
//! This is where the paper's mechanism executes: at every solver step
//! the pipeline walks the (block, branch) sites in order; a `Compute`
//! decision runs the branch's AOT executable and refills the layer
//! cache, a `Reuse` decision re-injects the cached delta through the
//! residual connection without touching the backend (paper Fig. 3).
//! Decisions come from one [`PlanRef`]: a dense
//! [`crate::cache::CachePlan`] (static policies; the inner loop's
//! scheduling cost is a single flat-array read per site — no string
//! keys, no map lookups) or a
//! [`crate::cache::StepPlanner`] deciding at runtime from per-site
//! observations (cache age, last observed delta drift).
//!
//! The execution surface is the step-driven [`GenSession`] state
//! machine ([`session`]): one solver step per [`GenSession::step`]
//! call, with per-step [`StepEvent`]s, interim latent access and early
//! exit — the seam the serving coordinator uses for cooperative
//! cancellation, deadlines and streaming progress. [`generate`] and
//! [`generate_from`] are thin drivers over it (bitwise-identical
//! output, pinned by `tests/session_parity.rs`).

pub mod session;

pub use session::{GenSession, SessionState, StepEvent};

use crate::util::error::Result;

use crate::cache::plan::PlanRef;
use crate::model::{Cond, Engine};
use crate::solvers::SolverKind;
use crate::tensor::{ComputeMode, Tensor};

/// One generation request's sampling configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub family: String,
    pub solver: SolverKind,
    pub steps: usize,
    /// classifier-free guidance scale; 1.0 disables CFG (single forward).
    pub cfg_scale: f32,
    pub seed: u64,
    /// Weight-matmul precision for every forward in this trajectory
    /// (f32 default; f16/bf16/int8 trade exactness for bandwidth —
    /// see docs/adr/006). Scoped around each step by [`GenSession`].
    pub compute: ComputeMode,
}

impl GenConfig {
    pub fn new(family: &str, solver: SolverKind, steps: usize) -> GenConfig {
        GenConfig {
            family: family.into(),
            solver,
            steps,
            cfg_scale: 1.0,
            seed: 0,
            compute: ComputeMode::F32,
        }
    }

    pub fn with_cfg(mut self, scale: f32) -> GenConfig {
        self.cfg_scale = scale;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> GenConfig {
        self.seed = seed;
        self
    }

    pub fn with_compute(mut self, mode: ComputeMode) -> GenConfig {
        self.compute = mode;
        self
    }

    pub fn uses_cfg(&self) -> bool {
        (self.cfg_scale - 1.0).abs() > 1e-6
    }
}

#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub branch_computes: usize,
    pub branch_reuses: usize,
    pub steps: usize,
    pub wall_seconds: f64,
}

impl GenStats {
    pub fn skip_fraction(&self) -> f64 {
        let total = self.branch_computes + self.branch_reuses;
        if total == 0 {
            0.0
        } else {
            self.branch_reuses as f64 / total as f64
        }
    }
}

pub struct GenOutput {
    /// `[batch, …latent_shape]` generated latents at t = 0.
    pub latent: Tensor,
    pub stats: GenStats,
}

/// Observer over *computed* branch deltas: (step, block, branch, delta).
pub type DeltaObserver<'a> = &'a mut dyn FnMut(usize, usize, &str, &Tensor);

/// Run one full denoising trajectory; the initial latent is drawn from
/// `cfg.seed`. A thin driver over [`GenSession`] — step the session
/// yourself for cancellation, progress or early exit.
pub fn generate(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    plan: PlanRef<'_>,
    mut observer: Option<DeltaObserver>,
) -> Result<GenOutput> {
    let mut session = GenSession::new(engine, cfg, cond, plan)?;
    while !session.is_done() {
        session.step_observed(observer.as_deref_mut())?;
    }
    Ok(session.finish())
}

/// Like [`generate`] but with a caller-provided initial latent — the
/// dynamic batcher uses this so each request's trajectory is seeded from
/// its own seed regardless of batch composition.
pub fn generate_from(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    x_init: Tensor,
    plan: PlanRef<'_>,
    mut observer: Option<DeltaObserver>,
) -> Result<GenOutput> {
    let mut session = GenSession::from_latent(engine, cfg, cond, x_init, plan)?;
    while !session.is_done() {
        session.step_observed(observer.as_deref_mut())?;
    }
    Ok(session.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_config_cfg_detection() {
        let c = GenConfig::new("image", SolverKind::Ddim, 10);
        assert!(!c.uses_cfg());
        assert!(c.with_cfg(1.5).uses_cfg());
    }

    #[test]
    fn stats_skip_fraction() {
        let s = GenStats { branch_computes: 30, branch_reuses: 10, ..Default::default() };
        assert!((s.skip_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(GenStats::default().skip_fraction(), 0.0);
    }
}
