//! Step-driven generation sessions: the resumable state machine behind
//! [`generate`](super::generate) / [`generate_from`](super::generate_from).
//!
//! The paper's mechanism is inherently per-step — SmoothCache decides
//! Compute/Reuse at every solver step — and the serving layer needs the
//! same granularity: cooperative cancellation between steps, per-step
//! progress events for streaming clients, latency deadlines, and early
//! exit with the interim latent. [`GenSession`] exposes exactly that
//! seam: construct with [`GenSession::new`] (or
//! [`GenSession::from_latent`] for a caller-provided initial latent),
//! call [`GenSession::step`] once per solver step — each returns a
//! [`StepEvent`] summarizing the decisions just executed — and
//! [`GenSession::finish`] at any point to take the latent out: after
//! the final step for a full trajectory, or earlier to abandon or
//! sample mid-trajectory ([`GenSession::latent`] also exposes the
//! interim latent without consuming the session).
//!
//! The one-shot drivers in the parent module are thin loops over this
//! type and produce bitwise-identical latents and identical decision
//! counters (pinned by `tests/session_parity.rs` across families,
//! solvers and every registry policy).

use std::time::Instant;

use crate::util::error::Result;

use super::{DeltaObserver, GenConfig, GenOutput, GenStats};
use crate::cache::plan::{PlanRef, StepObs};
use crate::cache::schedule::Decision;
use crate::model::{Cond, Engine};
use crate::solvers::{cfg_merge, SolverRun};
use crate::tensor::{quant, Tensor};
use crate::util::rng::Rng;

/// Summary of one executed solver step, returned by
/// [`GenSession::step`].
#[derive(Clone, Copy, Debug)]
pub struct StepEvent {
    /// 0-based index of the step that just executed.
    pub step: usize,
    /// Total steps in the trajectory.
    pub steps: usize,
    /// Branch sites computed in this step.
    pub computes: usize,
    /// Branch sites that re-injected a cached delta in this step.
    pub reuses: usize,
    /// Largest per-refresh relative-L1 drift measured in this step.
    /// `None` for static plans (drift is only tracked under a dynamic
    /// planner) and on steps where no refresh had a previous delta to
    /// compare against.
    pub max_drift: Option<f64>,
    /// True when this was the trajectory's final step.
    pub done: bool,
}

/// One in-flight denoising trajectory, advanced one solver step at a
/// time. See the module docs for the step/finish contract.
pub struct GenSession<'a> {
    engine: &'a Engine,
    cfg: GenConfig,
    plan: PlanRef<'a>,
    dynamic: bool,
    run: SolverRun,
    rng: Rng,
    x: Tensor,
    cond_eff: Cond,
    batch: usize,
    batch_eff: usize,
    sites: Vec<(usize, String)>,
    // per-site state, indexed by site position (no string keys):
    cache: Vec<Option<Tensor>>,
    filled_at: Vec<Option<usize>>,
    // drift feedback for dynamic planners: relative L1 error between a
    // freshly computed delta and the cached one it replaces. Only
    // tracked when a StepPlanner is driving — static plans skip the
    // extra tensor pass entirely.
    last_drift: Vec<Option<f64>>,
    stats: GenStats,
    i: usize,
    t_start: Instant,
    /// wall-clock seconds accumulated by earlier segments of a parked /
    /// resumed session (0 for a session that never parked).
    wall_accum: f64,
}

/// An owned, engine-independent snapshot of a [`GenSession`] taken at a
/// solver-step boundary ([`GenSession::snapshot`]) — the park/resume
/// seam of the preemptive scheduler (docs/adr/007).
///
/// It captures *everything* the trajectory depends on: the interim
/// latent, every per-site cached delta with its fill step, the dynamic
/// planner's drift feedback, the solver's multistep history, and the
/// stochastic-solver RNG state. Because engine weights are a
/// deterministic function of the artifacts, resuming on **any** replica
/// ([`GenSession::resume`]) continues the trajectory bitwise-identically
/// to an uninterrupted run — pinned at every step boundary for every
/// registry policy by `tests/session_parity.rs`.
#[derive(Clone)]
pub struct SessionState {
    cfg: GenConfig,
    dynamic: bool,
    run: SolverRun,
    rng: Rng,
    x: Tensor,
    cond_eff: Cond,
    batch: usize,
    batch_eff: usize,
    cache: Vec<Option<Tensor>>,
    filled_at: Vec<Option<usize>>,
    last_drift: Vec<Option<f64>>,
    stats: GenStats,
    i: usize,
    wall_seconds: f64,
}

impl SessionState {
    /// Steps already executed (the index the next step would run).
    pub fn step(&self) -> usize {
        self.i
    }

    /// Total solver steps in the trajectory.
    pub fn total_steps(&self) -> usize {
        self.cfg.steps
    }

    /// True when the snapshot was taken after the final step.
    pub fn is_done(&self) -> bool {
        self.i >= self.cfg.steps
    }

    /// The (padded) batch size the session executes at.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The generation configuration the session was opened with.
    pub fn config(&self) -> &GenConfig {
        &self.cfg
    }
}

impl<'a> GenSession<'a> {
    /// Open a session whose initial latent is drawn from `cfg.seed`
    /// (the [`generate`](super::generate) entry point).
    pub fn new(
        engine: &'a Engine,
        cfg: &GenConfig,
        cond: &Cond,
        plan: PlanRef<'a>,
    ) -> Result<GenSession<'a>> {
        let fm = engine.family_manifest(&cfg.family)?.clone();
        let batch = cond.batch(fm.cond_len);
        if batch == 0 {
            return Err(crate::err!("empty batch"));
        }
        let mut rng = Rng::new(cfg.seed);
        let mut latent_shape = vec![batch];
        latent_shape.extend(&fm.latent_shape);
        let x0 = SolverRun::init_latent(latent_shape, &mut rng);
        GenSession::from_latent(engine, cfg, cond, x0, plan)
    }

    /// Open a session over a caller-provided initial latent (the
    /// [`generate_from`](super::generate_from) entry point — the
    /// dynamic batcher seeds each request's latent from its own seed
    /// regardless of batch composition).
    pub fn from_latent(
        engine: &'a Engine,
        cfg: &GenConfig,
        cond: &Cond,
        x_init: Tensor,
        plan: PlanRef<'a>,
    ) -> Result<GenSession<'a>> {
        let t_start = Instant::now();
        let fm = engine.family_manifest(&cfg.family)?.clone();
        let batch = cond.batch(fm.cond_len);
        if batch == 0 {
            return Err(crate::err!("empty batch"));
        }
        if x_init.dim0() != batch {
            return Err(crate::err!("x_init batch {} != cond batch {batch}", x_init.dim0()));
        }
        // Static plans are checked against this exact configuration up
        // front: step count and the family's site enumeration must match —
        // a plan built for a different family fails loudly here instead of
        // silently computing at unmatched sites.
        if let PlanRef::Plan(p) = plan {
            p.validate_for(&fm, cfg.steps)?;
        }
        let dynamic = matches!(plan, PlanRef::Planner(_));

        let rng = Rng::new(cfg.seed ^ 0x50D4_11CE);
        let run = SolverRun::new(cfg.solver, cfg.steps);

        // CFG: the conditional and null batches run concatenated.
        let cond_eff = if cfg.uses_cfg() {
            cond.cat(&cond.null_like(fm.num_classes, fm.cond_len))
        } else {
            cond.clone()
        };
        let batch_eff = if cfg.uses_cfg() { 2 * batch } else { batch };

        let sites = fm.branch_sites();
        let n_sites = sites.len();
        Ok(GenSession {
            engine,
            cfg: cfg.clone(),
            plan,
            dynamic,
            run,
            rng,
            x: x_init,
            cond_eff,
            batch,
            batch_eff,
            sites,
            cache: vec![None; n_sites],
            filled_at: vec![None; n_sites],
            last_drift: vec![None; n_sites],
            stats: GenStats::default(),
            i: 0,
            t_start,
            wall_accum: 0.0,
        })
    }

    /// Snapshot the session at the current step boundary into an owned
    /// [`SessionState`]. The session itself is untouched — the caller
    /// that parks a session simply drops it after snapshotting.
    pub fn snapshot(&self) -> SessionState {
        SessionState {
            cfg: self.cfg.clone(),
            dynamic: self.dynamic,
            run: self.run.clone(),
            rng: self.rng.clone(),
            x: self.x.clone(),
            cond_eff: self.cond_eff.clone(),
            batch: self.batch,
            batch_eff: self.batch_eff,
            cache: self.cache.clone(),
            filled_at: self.filled_at.clone(),
            last_drift: self.last_drift.clone(),
            stats: self.stats.clone(),
            i: self.i,
            wall_seconds: self.wall_accum + self.t_start.elapsed().as_secs_f64(),
        }
    }

    /// Reopen a parked session from a [`SessionState`] snapshot — on the
    /// same engine or any other replica of it. The caller re-resolves
    /// `plan` for the snapshot's policy (plan resolution is
    /// deterministic, so the resumed trajectory is bitwise identical to
    /// an uninterrupted one); a plan of the wrong kind, family geometry
    /// or step count fails loudly here instead of silently diverging.
    pub fn resume(
        engine: &'a Engine,
        state: SessionState,
        plan: PlanRef<'a>,
    ) -> Result<GenSession<'a>> {
        let t_start = Instant::now();
        let fm = engine.family_manifest(&state.cfg.family)?.clone();
        if let PlanRef::Plan(p) = plan {
            p.validate_for(&fm, state.cfg.steps)?;
        }
        let dynamic = matches!(plan, PlanRef::Planner(_));
        if dynamic != state.dynamic {
            return Err(crate::err!(
                "resume plan kind mismatch: session was {} but plan is {}",
                if state.dynamic { "dynamic" } else { "static" },
                if dynamic { "dynamic" } else { "static" },
            ));
        }
        let sites = fm.branch_sites();
        if sites.len() != state.cache.len() {
            return Err(crate::err!(
                "resume site mismatch: snapshot has {} sites, family {} has {}",
                state.cache.len(),
                state.cfg.family,
                sites.len()
            ));
        }
        if state.i > state.cfg.steps {
            return Err(crate::err!(
                "corrupt snapshot: step {} past the {}-step trajectory",
                state.i,
                state.cfg.steps
            ));
        }
        Ok(GenSession {
            engine,
            cfg: state.cfg,
            plan,
            dynamic,
            run: state.run,
            rng: state.rng,
            x: state.x,
            cond_eff: state.cond_eff,
            batch: state.batch,
            batch_eff: state.batch_eff,
            sites,
            cache: state.cache,
            filled_at: state.filled_at,
            last_drift: state.last_drift,
            stats: state.stats,
            i: state.i,
            t_start,
            wall_accum: state.wall_seconds,
        })
    }

    /// Total solver steps in the trajectory.
    pub fn total_steps(&self) -> usize {
        self.cfg.steps
    }

    /// Steps executed so far (equivalently: the index the next
    /// [`GenSession::step`] call will run).
    pub fn current_step(&self) -> usize {
        self.i
    }

    /// True once every step has executed — [`GenSession::step`] errors
    /// past this point; [`GenSession::finish`] takes the result out.
    pub fn is_done(&self) -> bool {
        self.i >= self.cfg.steps
    }

    /// The interim latent after [`GenSession::current_step`] steps
    /// (mid-trajectory observation; [`GenSession::finish`] moves it out).
    pub fn latent(&self) -> &Tensor {
        &self.x
    }

    /// Decision counters accumulated so far.
    pub fn stats(&self) -> &GenStats {
        &self.stats
    }

    /// Execute the next solver step.
    pub fn step(&mut self) -> Result<StepEvent> {
        self.step_observed(None)
    }

    /// Like [`GenSession::step`], additionally reporting every computed
    /// branch delta to `observer` (the calibration hook).
    ///
    /// Every engine call inside the step runs under the session's
    /// [`GenConfig::compute`] mode — scoped here (not at session
    /// construction) so a session stepped from different threads still
    /// sees its own precision choice.
    pub fn step_observed(&mut self, observer: Option<DeltaObserver>) -> Result<StepEvent> {
        let mode = self.cfg.compute;
        quant::with_compute(mode, || self.step_inner(observer))
    }

    fn step_inner(&mut self, mut observer: Option<DeltaObserver>) -> Result<StepEvent> {
        if self.is_done() {
            return Err(crate::err!(
                "GenSession: step() past the end of the {}-step trajectory",
                self.cfg.steps
            ));
        }
        let i = self.i;
        let t = self.run.model_t(i) as f32;
        let t_vec = vec![t; self.batch_eff];
        let emb = if self.cfg.uses_cfg() {
            let x_in = Tensor::cat0(&[&self.x, &self.x]);
            self.engine.embed(&self.cfg.family, &x_in, &t_vec, &self.cond_eff)?
        } else {
            self.engine.embed(&self.cfg.family, &self.x, &t_vec, &self.cond_eff)?
        };
        let ctx = self.engine.make_step_ctx(&emb)?;
        let mut tokens = emb.tokens;
        let mut computes = 0usize;
        let mut reuses = 0usize;
        let mut max_drift: Option<f64> = None;

        for (s_idx, (block, br)) in self.sites.iter().enumerate() {
            let decision = match self.plan {
                PlanRef::Plan(p) => p.decision(i, s_idx),
                PlanRef::Planner(sp) => {
                    let obs = StepObs {
                        filled_at: self.filled_at[s_idx],
                        last_drift: self.last_drift[s_idx],
                    };
                    sp.decide(i, s_idx, &obs)
                }
            };
            let computed = matches!(decision, Decision::Compute);
            match decision {
                Decision::Compute => {
                    let d = self.engine.branch(&self.cfg.family, *block, br, &tokens, &ctx)?;
                    if let Some(obs) = observer.as_deref_mut() {
                        obs(i, *block, br, &d);
                    }
                    computes += 1;
                    if self.dynamic {
                        if let Some(old) = &self.cache[s_idx] {
                            let drift = d.rel_l1_error(old);
                            self.last_drift[s_idx] = Some(drift);
                            max_drift = Some(max_drift.map_or(drift, |m: f64| m.max(drift)));
                        }
                    }
                    self.filled_at[s_idx] = Some(i);
                    // add first, then move into the cache — the compute
                    // path stores the delta without cloning it
                    tokens.add_inplace(&d);
                    self.cache[s_idx] = Some(d);
                }
                Decision::Reuse { .. } => {
                    reuses += 1;
                    // re-inject the cached delta by reference — the
                    // reuse hot path copies no tensor at all
                    let d = self.cache[s_idx].as_ref().ok_or_else(|| {
                        crate::err!(
                            "cache miss at step {i} site {block}.{br}: \
                             plan decided Reuse before any compute"
                        )
                    })?;
                    tokens.add_inplace(d);
                }
            }
            // fine-granularity tracing (docs/adr/009): stages into the
            // executor thread's buffer, a single relaxed load otherwise —
            // purely observational, the trajectory never depends on it
            crate::obs::site_event(i, s_idx, computed, self.last_drift[s_idx]);
        }

        let out = self.engine.final_head(&self.cfg.family, &tokens, &ctx)?;
        let model_out = if self.cfg.uses_cfg() {
            let c = out.batch_slice(0, self.batch);
            let u = out.batch_slice(self.batch, 2 * self.batch);
            cfg_merge(&c, &u, self.cfg.cfg_scale)
        } else {
            out
        };
        self.x = self.run.step(i, &self.x, &model_out, &mut self.rng);
        self.stats.branch_computes += computes;
        self.stats.branch_reuses += reuses;
        self.i += 1;
        Ok(StepEvent {
            step: i,
            steps: self.cfg.steps,
            computes,
            reuses,
            max_drift,
            done: self.is_done(),
        })
    }

    /// Consume the session, returning the current latent and stats —
    /// after the last step for a full trajectory, or earlier for an
    /// early exit (`stats.steps` records how many steps actually ran).
    pub fn finish(mut self) -> GenOutput {
        self.stats.steps = self.i;
        self.stats.wall_seconds = self.wall_accum + self.t_start.elapsed().as_secs_f64();
        GenOutput { latent: self.x, stats: self.stats }
    }
}
