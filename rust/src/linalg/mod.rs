//! Dense symmetric linear algebra (substrate).
//!
//! Implements exactly what the Fréchet Feature Distance needs (DESIGN.md
//! section 3: the FID substitution): covariance estimation, a cyclic
//! Jacobi eigensolver for symmetric matrices, and the PSD matrix square
//! root built on it. Row-major `d x d` matrices as `Vec<f64>`.

/// Row-major square matrix helper.
#[derive(Clone, Debug)]
pub struct Mat {
    pub d: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(d: usize) -> Mat {
        Mat { d, a: vec![0.0; d * d] }
    }

    pub fn eye(d: usize) -> Mat {
        let mut m = Mat::zeros(d);
        for i in 0..d {
            m.a[i * d + i] = 1.0;
        }
        m
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.d + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.d + j] = v;
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.d, other.d);
        let d = self.d;
        let mut out = Mat::zeros(d);
        for i in 0..d {
            for k in 0..d {
                let aik = self.a[i * d + k];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..d {
                    out.a[i * d + j] += aik * other.a[k * d + j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let d = self.d;
        let mut out = Mat::zeros(d);
        for i in 0..d {
            for j in 0..d {
                out.a[j * d + i] = self.a[i * d + j];
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.d).map(|i| self.a[i * self.d + i]).sum()
    }

    pub fn symmetrize(&mut self) {
        let d = self.d;
        for i in 0..d {
            for j in (i + 1)..d {
                let v = 0.5 * (self.a[i * d + j] + self.a[j * d + i]);
                self.a[i * d + j] = v;
                self.a[j * d + i] = v;
            }
        }
    }

    pub fn max_offdiag_abs(&self) -> f64 {
        let d = self.d;
        let mut m = 0.0f64;
        for i in 0..d {
            for j in 0..d {
                if i != j {
                    m = m.max(self.a[i * d + j].abs());
                }
            }
        }
        m
    }
}

/// Sample mean of rows. `xs` is n rows of dimension d, row-major.
pub fn mean_rows(xs: &[f64], n: usize, d: usize) -> Vec<f64> {
    assert_eq!(xs.len(), n * d);
    let mut mu = vec![0.0; d];
    for r in 0..n {
        for j in 0..d {
            mu[j] += xs[r * d + j];
        }
    }
    for v in &mut mu {
        *v /= n as f64;
    }
    mu
}

/// Unbiased sample covariance of rows.
pub fn covariance(xs: &[f64], n: usize, d: usize) -> Mat {
    assert!(n >= 2, "covariance needs >= 2 samples");
    let mu = mean_rows(xs, n, d);
    let mut c = Mat::zeros(d);
    for r in 0..n {
        for i in 0..d {
            let xi = xs[r * d + i] - mu[i];
            for j in i..d {
                c.a[i * d + j] += xi * (xs[r * d + j] - mu[j]);
            }
        }
    }
    let norm = 1.0 / (n - 1) as f64;
    for i in 0..d {
        for j in i..d {
            let v = c.a[i * d + j] * norm;
            c.a[i * d + j] = v;
            c.a[j * d + i] = v;
        }
    }
    c
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors as columns of V): A = V diag(w) V^T.
pub fn jacobi_eigh(m: &Mat, max_sweeps: usize, tol: f64) -> (Vec<f64>, Mat) {
    let d = m.d;
    let mut a = m.clone();
    a.symmetrize();
    let mut v = Mat::eye(d);
    for _sweep in 0..max_sweeps {
        if a.max_offdiag_abs() < tol {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = a.get(p, q);
                if apq.abs() < tol * 1e-3 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of a
                for k in 0..d {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..d {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                // accumulate eigenvectors
                for k in 0..d {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let w = (0..d).map(|i| a.get(i, i)).collect();
    (w, v)
}

/// PSD matrix square root via eigendecomposition (negative eigenvalues,
/// which arise from numerical noise, are clamped to zero).
pub fn sqrtm_psd(m: &Mat) -> Mat {
    let d = m.d;
    let (w, v) = jacobi_eigh(m, 64, 1e-12);
    let mut out = Mat::zeros(d);
    // out = V diag(sqrt(max(w,0))) V^T
    for k in 0..d {
        let s = w[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..d {
            let vik = v.get(i, k) * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..d {
                out.a[i * d + j] += vik * v.get(j, k);
            }
        }
    }
    out
}

/// Fréchet distance squared between Gaussians (mu1, C1), (mu2, C2):
///   |mu1-mu2|^2 + tr(C1 + C2 - 2 (C1^{1/2} C2 C1^{1/2})^{1/2})
pub fn frechet_distance_sq(mu1: &[f64], c1: &Mat, mu2: &[f64], c2: &Mat) -> f64 {
    assert_eq!(mu1.len(), mu2.len());
    let dmu: f64 = mu1.iter().zip(mu2).map(|(a, b)| (a - b) * (a - b)).sum();
    let s1 = sqrtm_psd(c1);
    let mut inner = s1.matmul(c2).matmul(&s1);
    inner.symmetrize();
    let covmean = sqrtm_psd(&inner);
    let d2 = dmu + c1.trace() + c2.trace() - 2.0 * covmean.trace();
    d2.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn eigh_diagonal_matrix() {
        let mut m = Mat::zeros(3);
        m.set(0, 0, 3.0);
        m.set(1, 1, 1.0);
        m.set(2, 2, 2.0);
        let (mut w, _) = jacobi_eigh(&m, 32, 1e-14);
        w.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(1);
        let d = 8;
        // random symmetric matrix
        let mut m = Mat::zeros(d);
        for i in 0..d {
            for j in i..d {
                let v = rng.normal();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let (w, v) = jacobi_eigh(&m, 64, 1e-14);
        // rebuild V diag(w) V^T
        let mut rec = Mat::zeros(d);
        for k in 0..d {
            for i in 0..d {
                for j in 0..d {
                    rec.a[i * d + j] += v.get(i, k) * w[k] * v.get(j, k);
                }
            }
        }
        for i in 0..d * d {
            assert!((rec.a[i] - m.a[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let mut rng = Rng::new(2);
        let d = 6;
        // PSD: A^T A
        let mut b = Mat::zeros(d);
        for i in 0..d * d {
            b.a[i] = rng.normal();
        }
        let psd = b.transpose().matmul(&b);
        let s = sqrtm_psd(&psd);
        let s2 = s.matmul(&s);
        for i in 0..d * d {
            assert!((s2.a[i] - psd.a[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn covariance_of_known_data() {
        // two dims, perfectly correlated
        let xs = vec![1.0, 2.0, 2.0, 4.0, 3.0, 6.0];
        let c = covariance(&xs, 3, 2);
        assert!((c.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((c.get(1, 1) - 4.0).abs() < 1e-12);
        assert!((c.get(0, 1) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn frechet_identical_is_zero() {
        let mut rng = Rng::new(3);
        let d = 4;
        let n = 50;
        let xs: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let mu = mean_rows(&xs, n, d);
        let c = covariance(&xs, n, d);
        let f = frechet_distance_sq(&mu, &c, &mu, &c);
        assert!(f.abs() < 1e-6, "f={f}");
    }

    #[test]
    fn frechet_increases_with_mean_shift() {
        let d = 3;
        let c = Mat::eye(d);
        let mu0 = vec![0.0; d];
        let f1 = frechet_distance_sq(&mu0, &c, &vec![1.0; d], &c);
        let f2 = frechet_distance_sq(&mu0, &c, &vec![2.0; d], &c);
        assert!((f1 - 3.0).abs() < 1e-9);
        assert!(f2 > f1);
    }

    #[test]
    fn frechet_detects_cov_difference() {
        let d = 2;
        let c1 = Mat::eye(d);
        let mut c2 = Mat::eye(d);
        c2.set(0, 0, 4.0);
        let mu = vec![0.0; d];
        // tr(1+4+... ) analytic: (2-2*... ) for diag: sum (1+4) - 2*sqrt(4)=5-4=1 plus dim2: 1+1-2=0
        let f = frechet_distance_sq(&mu, &c1, &mu, &c2);
        assert!((f - 1.0).abs() < 1e-9, "f={f}");
    }
}
