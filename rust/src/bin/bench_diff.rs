//! bench_diff — the regression gate over `BENCH_<area>.json` files.
//!
//! Usage: `bench_diff BASELINE.json CANDIDATE.json [--tol PCT]`
//!
//! Compares a candidate bench report against a committed baseline under
//! per-metric tolerance thresholds (the baseline's embedded `tol_pct`
//! wins; `--tol` sets the default band, 10% when omitted), prints a
//! readable comparison table, and exits:
//!
//! * `0` — gate passed (every metric within tolerance, or improved);
//! * `1` — at least one metric regressed beyond tolerance;
//! * `2` — structural failure: unreadable file, schema violation,
//!   baseline metric missing from the candidate, or unit/direction/area
//!   mismatch.
//!
//! `scripts/verify.sh` and CI run this against `BENCH_baseline/` after
//! the smoke benches; see docs/benchmarks.md for the refresh workflow.

use smoothcache::util::bench::report::{diff, BenchReport};
use smoothcache::util::bench::Args;
use smoothcache::util::error::Result;

const USAGE: &str = "usage: bench_diff BASELINE.json CANDIDATE.json [--tol PCT]";

fn run() -> Result<i32> {
    let args = Args::parse();
    let tol = args.f64("tol", 10.0)?;
    let pos = args.positional();
    args.finish()?;
    let [base_path, cand_path] = match pos.as_slice() {
        [b, c] => [b.clone(), c.clone()],
        _ => smoothcache::bail!("expected exactly two report paths, got {}\n{USAGE}", pos.len()),
    };
    let base = BenchReport::load(&base_path)?;
    let cand = BenchReport::load(&cand_path)?;
    let d = diff(&base, &cand, tol);
    println!("bench_diff: area {:?}, baseline {base_path}, candidate {cand_path}", base.area);
    print!("{}", d.to_table().to_string());
    println!("{}", d.summary());
    if d.hard_errors() > 0 {
        Ok(2)
    } else if d.regressions() > 0 {
        Ok(1)
    } else {
        Ok(0)
    }
}

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("bench_diff: {e}");
            std::process::exit(2);
        }
    }
}
