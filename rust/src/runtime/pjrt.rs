//! PJRT backend (cargo feature `pjrt`): load AOT HLO-text artifacts and
//! execute them through the `xla` crate (PJRT C API, CPU plugin).
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b`. Interchange is HLO **text** (jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids).
//!
//! Weights are uploaded once as device-resident [`xla::PjRtBuffer`]s and
//! passed by reference on every call (`execute_b`), so the request path
//! transfers only activations. PJRT handles are not `Send`/`Sync`; the
//! engine owns this backend on a single executor thread.
//!
//! NOTE: the `xla` dependency is intentionally not declared in
//! Cargo.toml (docs/adr/001-zero-dependency-default-build.md); enabling
//! this feature requires vendoring xla-rs and adding it to
//! `[dependencies]`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::{Backend, EmbedOut, HostValue, RuntimeStats, StepCtx};
use crate::model::manifest::FamilyManifest;
use crate::model::weights::WeightStore;
use crate::model::Cond;
use crate::tensor::Tensor;
use crate::util::error::{Context, Result};

/// A compiled PJRT executable plus its interface metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub num_outputs: usize,
}

/// PJRT client + executable cache. One per executor thread.
pub struct Runtime {
    client: xla::PjRtClient,
    stats: std::cell::RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| crate::err!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, stats: Default::default() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RuntimeStats::default();
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path, num_outputs: usize) -> Result<Executable> {
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| crate::err!("non-utf8 path"))?,
        )
        .map_err(|e| crate::err!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| crate::err!("compile {path:?}: {e:?}"))?;
        let mut s = self.stats.borrow_mut();
        s.compiles += 1;
        s.compile_seconds += t.elapsed().as_secs_f64();
        Ok(Executable {
            exe,
            name: path.file_name().unwrap().to_string_lossy().into_owned(),
            num_outputs,
        })
    }

    /// Upload a host value to a device-resident buffer.
    pub fn upload(&self, v: &HostValue) -> Result<xla::PjRtBuffer> {
        let t = Instant::now();
        let buf = match v {
            HostValue::F32(t) => self
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .map_err(|e| crate::err!("upload f32: {e:?}"))?,
            HostValue::I32 { shape, data } => self
                .client
                .buffer_from_host_buffer::<i32>(data, shape, None)
                .map_err(|e| crate::err!("upload i32: {e:?}"))?,
        };
        let mut s = self.stats.borrow_mut();
        s.uploads += 1;
        s.upload_seconds += t.elapsed().as_secs_f64();
        Ok(buf)
    }

    /// Execute with device-resident argument buffers; download all tuple
    /// outputs as f32 host tensors.
    pub fn execute(&self, exe: &Executable, args: &[&xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        let t = Instant::now();
        let out = exe
            .exe
            .execute_b(args)
            .map_err(|e| crate::err!("execute {}: {e:?}", exe.name))?;
        let result = out
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| crate::err!("execute {}: empty result", exe.name))?;
        let lit = result
            .to_literal_sync()
            .map_err(|e| crate::err!("download {}: {e:?}", exe.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| crate::err!("untuple {}: {e:?}", exe.name))?;
        if parts.len() != exe.num_outputs {
            return Err(crate::err!(
                "{}: expected {} outputs, got {}",
                exe.name,
                exe.num_outputs,
                parts.len()
            ));
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p
                .array_shape()
                .map_err(|e| crate::err!("shape {}: {e:?}", exe.name))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = p
                .to_vec::<f32>()
                .map_err(|e| crate::err!("to_vec {}: {e:?}", exe.name))?;
            tensors.push(Tensor::new(dims, data));
        }
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.exec_seconds += t.elapsed().as_secs_f64();
        Ok(tensors)
    }
}

/// Artifact registry: resolves artifact file → compiled executable,
/// compiling lazily and caching the handle.
pub struct Registry {
    pub dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Registry {
    pub fn new(dir: PathBuf) -> Registry {
        Registry { dir, cache: Default::default() }
    }

    pub fn get(
        &self,
        rt: &Runtime,
        file: &str,
        num_outputs: usize,
    ) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        if !path.exists() {
            return Err(crate::err!(
                "artifact {file} not found in {:?} — run `make artifacts`",
                self.dir
            ));
        }
        let exe = std::rc::Rc::new(
            rt.load_hlo(&path, num_outputs)
                .with_context(|| format!("loading {file}"))?,
        );
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Step payload: device-resident per-step conditioning (c uploaded once
/// per step, not once per branch — the branch hot path uploads only the
/// tokens).
struct PjrtStepCtx {
    c_buf: xla::PjRtBuffer,
    cond_buf: Option<xla::PjRtBuffer>,
}

/// The [`Backend`] over PJRT: artifact executables + device weights.
pub struct PjrtBackend {
    rt: Runtime,
    registry: Registry,
    /// family → resolved tensor name → device buffer (uploaded at load).
    device_weights: HashMap<String, HashMap<String, xla::PjRtBuffer>>,
}

impl PjrtBackend {
    pub fn open(dir: PathBuf) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            rt: Runtime::cpu()?,
            registry: Registry::new(dir),
            device_weights: HashMap::new(),
        })
    }

    fn family_weights(&self, family: &str) -> Result<&HashMap<String, xla::PjRtBuffer>> {
        self.device_weights
            .get(family)
            .ok_or_else(|| crate::err!("family {family:?} not loaded in pjrt backend"))
    }

    fn weight_buffers<'a>(
        &'a self,
        family: &str,
        templates: &[String],
        block: usize,
    ) -> Result<Vec<&'a xla::PjRtBuffer>> {
        let dw = self.family_weights(family)?;
        templates
            .iter()
            .map(|tpl| {
                let name = tpl.replace("{i}", &block.to_string());
                dw.get(&name)
                    .ok_or_else(|| crate::err!("device weight {name:?} missing"))
            })
            .collect()
    }

    fn exec_entry(
        &self,
        fm: &FamilyManifest,
        entry_name: &str,
        batch: usize,
        host_args: &[HostValue],
        extra_device: &[&xla::PjRtBuffer],
        block: usize,
    ) -> Result<Vec<Tensor>> {
        let entry = fm.entry(entry_name)?;
        let file = entry.artifacts.get(&batch).ok_or_else(|| {
            crate::err!(
                "{}/{entry_name}: unsupported batch {batch} (have {:?})",
                fm.name,
                entry.artifacts.keys().collect::<Vec<_>>()
            )
        })?;
        let exe = self.registry.get(&self.rt, file, outputs_of(fm, entry_name))?;
        let wbufs = self.weight_buffers(&fm.name, &entry.weights, block)?;
        let uploaded: Vec<xla::PjRtBuffer> =
            host_args.iter().map(|v| self.rt.upload(v)).collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = uploaded.iter().collect();
        args.extend_from_slice(extra_device);
        args.extend(wbufs);
        self.rt.execute(&exe, &args)
    }

    fn step_payload<'a>(&self, ctx: &'a StepCtx) -> Result<&'a PjrtStepCtx> {
        ctx.payload::<PjrtStepCtx>()
            .ok_or_else(|| crate::err!("step ctx was not produced by the pjrt backend"))
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt-{}", self.rt.platform())
    }

    /// Upload every weight tensor to the device once.
    fn load_family(&mut self, fm: &FamilyManifest, weights: WeightStore) -> Result<()> {
        if self.device_weights.contains_key(&fm.name) {
            return Ok(());
        }
        let mut dw = HashMap::new();
        for name in weights.names() {
            let t = weights.get(name)?;
            dw.insert(name.clone(), self.rt.upload(&HostValue::F32(t.clone()))?);
        }
        self.device_weights.insert(fm.name.clone(), dw);
        Ok(())
    }

    /// Pre-compile every executable for the given batch size (avoids
    /// first-request compile latency; used by the server warmup).
    fn warmup(&mut self, fm: &FamilyManifest, batch: usize) -> Result<()> {
        for (ename, entry) in &fm.entries {
            let file = entry
                .artifacts
                .get(&batch)
                .ok_or_else(|| crate::err!("{}/{ename}: no batch-{batch} artifact", fm.name))?;
            self.registry.get(&self.rt, file, outputs_of(fm, ename))?;
        }
        Ok(())
    }

    fn embed(&self, fm: &FamilyManifest, x: &Tensor, t: &[f32], cond: &Cond) -> Result<EmbedOut> {
        let batch = x.dim0();
        assert_eq!(t.len(), batch, "t batch mismatch");
        let cond_val = match cond {
            Cond::Label(l) => {
                assert_eq!(l.len(), batch);
                HostValue::i32(vec![batch], l.clone())
            }
            Cond::Prompt(p) => {
                assert_eq!(p.len(), batch * fm.cond_len);
                HostValue::i32(vec![batch, fm.cond_len], p.clone())
            }
        };
        let host_args = vec![
            HostValue::F32(x.clone()),
            HostValue::F32(Tensor::new(vec![batch], t.to_vec())),
            cond_val,
        ];
        let mut out = self.exec_entry(fm, "embed", batch, &host_args, &[], 0)?;
        let cond_t = if out.len() == 3 { Some(out.pop().unwrap()) } else { None };
        let c = out.pop().unwrap();
        let tokens = out.pop().unwrap();
        Ok(EmbedOut { tokens, c, cond: cond_t })
    }

    /// Upload the per-step conditioning once (reused across all branches
    /// of the step).
    fn make_step_ctx(&self, embed: &EmbedOut) -> Result<StepCtx> {
        let payload = PjrtStepCtx {
            c_buf: self.rt.upload(&HostValue::F32(embed.c.clone()))?,
            cond_buf: match &embed.cond {
                Some(c) => Some(self.rt.upload(&HostValue::F32(c.clone()))?),
                None => None,
            },
        };
        Ok(StepCtx::new(embed.tokens.dim0(), Box::new(payload)))
    }

    fn branch(
        &self,
        fm: &FamilyManifest,
        block: usize,
        branch: &str,
        tokens: &Tensor,
        ctx: &StepCtx,
    ) -> Result<Tensor> {
        let payload = self.step_payload(ctx)?;
        let entry_name = format!("branch.{branch}");
        let entry = fm.entry(&entry_name)?;
        let needs_cond = entry.inputs.iter().any(|i| i == "cond");
        let host_args = vec![HostValue::F32(tokens.clone())];
        let mut extra: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2);
        if needs_cond {
            extra.push(
                payload
                    .cond_buf
                    .as_ref()
                    .ok_or_else(|| crate::err!("{entry_name} needs cond tokens"))?,
            );
        }
        extra.push(&payload.c_buf);
        let mut out = self.exec_entry(fm, &entry_name, ctx.batch, &host_args, &extra, block)?;
        Ok(out.pop().unwrap())
    }

    fn final_head(&self, fm: &FamilyManifest, tokens: &Tensor, ctx: &StepCtx) -> Result<Tensor> {
        let payload = self.step_payload(ctx)?;
        let host_args = vec![HostValue::F32(tokens.clone())];
        let mut out =
            self.exec_entry(fm, "final", ctx.batch, &host_args, &[&payload.c_buf], 0)?;
        Ok(out.pop().unwrap())
    }

    fn stats(&self) -> RuntimeStats {
        self.rt.stats()
    }

    fn reset_stats(&self) {
        self.rt.reset_stats()
    }
}

/// Tuple arity of each entry's output.
fn outputs_of(fm: &FamilyManifest, entry: &str) -> usize {
    match entry {
        "embed" => {
            if fm.cond_len > 0 {
                3
            } else {
                2
            }
        }
        _ => 1,
    }
}
