//! Execution backends: the seam between the model-agnostic serving /
//! caching layers and whatever actually runs the DiT math.
//!
//! The SmoothCache policy machinery (calibration, schedules, the
//! coordinator, the TCP server) only ever needs four operations at the
//! paper's caching granularity — embed, branch, final head, plus a
//! per-step context — so those are the [`Backend`] trait. Two
//! implementations exist:
//!
//! * [`reference`] — a pure-Rust CPU DiT forward over the in-tree
//!   [`crate::tensor`] substrate with deterministic weight synthesis.
//!   Always available; the default. Lets calibration, schedule
//!   generation, serving and every integration test run fully offline.
//! * `pjrt` *(cargo feature `pjrt`; module `runtime::pjrt`)* — loads
//!   the AOT HLO-text
//!   artifacts produced by `python/compile/aot.py` through the PJRT C
//!   API (`xla` crate) and keeps weights device-resident. See
//!   DESIGN.md §"Backend seam".
//!
//! Backends are selected by [`select_backend`]: `SMOOTHCACHE_BACKEND`
//! (`reference` | `pjrt`) wins; otherwise PJRT is used when compiled in
//! and the artifacts directory holds a manifest, else the reference
//! backend.
//!
//! Backend handles are not `Send`/`Sync` in general (PJRT buffers are
//! thread-bound); each engine owns its backend on one executor thread
//! and coordinator threads talk to it over channels. Backends that *can*
//! replicate (reference) may run one independent instance per executor
//! in the coordinator's worker pool — see [`backend_supports_replicas`].

pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::any::Any;

use crate::model::manifest::FamilyManifest;
use crate::model::weights::WeightStore;
use crate::model::Cond;
use crate::tensor::Tensor;
use crate::util::error::Result;

/// Host-side executable input (f32 tensor or i32 index array).
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn f32(t: Tensor) -> HostValue {
        HostValue::F32(t)
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostValue {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32 { shape, .. } => shape,
        }
    }
}

/// Cumulative runtime counters (perf pass + MAC/latency accounting).
/// `uploads`/`compiles` stay zero on backends without a device transfer
/// or compile stage.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_seconds: f64,
    pub uploads: u64,
    pub upload_seconds: f64,
    pub compiles: u64,
    pub compile_seconds: f64,
}

/// Output of the embed entry for one (batch, t) invocation.
pub struct EmbedOut {
    /// `[B, S, D]` patchified + positional tokens.
    pub tokens: Tensor,
    /// `[B, D]` adaLN conditioning vector.
    pub c: Tensor,
    /// `[B, Sc, D]` cross-attention tokens (prompt families only).
    pub cond: Option<Tensor>,
}

/// Per-step context produced by [`Backend::make_step_ctx`] and consumed
/// by every branch / final-head call of that solver step. The payload is
/// backend-specific (the PJRT backend stores device-resident buffers so
/// the branch hot path uploads only the tokens; the reference backend
/// stores host tensors).
pub struct StepCtx {
    pub batch: usize,
    inner: Box<dyn Any>,
}

impl StepCtx {
    pub fn new(batch: usize, inner: Box<dyn Any>) -> StepCtx {
        StepCtx { batch, inner }
    }

    /// Recover the backend-specific payload. Backends panic-free
    /// downcast and error on a foreign context.
    pub fn payload<T: 'static>(&self) -> Option<&T> {
        self.inner.downcast_ref::<T>()
    }
}

/// A DiT execution backend at SmoothCache's caching granularity.
///
/// The contract mirrors the branch decomposition of
/// `python/compile/model.py`: one `embed` per (step, batch), then one
/// `branch` call per (block, branch-type) site — each returning the
/// gated pre-residual delta the pipeline may cache — and one
/// `final_head` per step. See docs/protocol.md for how requests reach
/// this trait and DESIGN.md for the layer map.
pub trait Backend {
    /// Short identifier ("reference", "pjrt-cpu", …).
    fn name(&self) -> String;

    /// Make a family executable: bind its weights (uploading to the
    /// device where applicable). Idempotent per family.
    fn load_family(&mut self, fm: &FamilyManifest, weights: WeightStore) -> Result<()>;

    /// Prepare for a batch size ahead of traffic (compile caches etc.).
    /// No-op for backends without a compile stage.
    fn warmup(&mut self, _fm: &FamilyManifest, _batch: usize) -> Result<()> {
        Ok(())
    }

    /// Run the embed entry: latent + t + conditioning → tokens, c, cond.
    fn embed(&self, fm: &FamilyManifest, x: &Tensor, t: &[f32], cond: &Cond) -> Result<EmbedOut>;

    /// Stage the per-step conditioning (reused across all branches of
    /// the step).
    fn make_step_ctx(&self, embed: &EmbedOut) -> Result<StepCtx>;

    /// Execute one branch site: returns the gated pre-residual delta.
    fn branch(
        &self,
        fm: &FamilyManifest,
        block: usize,
        branch: &str,
        tokens: &Tensor,
        ctx: &StepCtx,
    ) -> Result<Tensor>;

    /// Execute the final head: tokens → epsilon/velocity prediction in
    /// latent shape.
    fn final_head(&self, fm: &FamilyManifest, tokens: &Tensor, ctx: &StepCtx) -> Result<Tensor>;

    fn stats(&self) -> RuntimeStats;

    fn reset_stats(&self);
}

/// The backend kind [`select_backend`] will construct — the single
/// resolver both backend construction and replica-pool sizing consult,
/// so the two can never disagree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BackendChoice {
    Reference,
    Pjrt,
}

fn backend_choice(manifest_on_disk: bool) -> Result<BackendChoice> {
    let choice = std::env::var("SMOOTHCACHE_BACKEND").unwrap_or_default();
    match choice.as_str() {
        "reference" => Ok(BackendChoice::Reference),
        "pjrt" => Ok(BackendChoice::Pjrt),
        "" => {
            if cfg!(feature = "pjrt") && manifest_on_disk {
                Ok(BackendChoice::Pjrt)
            } else {
                Ok(BackendChoice::Reference)
            }
        }
        other => Err(crate::err!(
            "unknown SMOOTHCACHE_BACKEND {other:?} (expected reference|pjrt)"
        )),
    }
}

/// Construct the backend for an artifacts directory.
///
/// `manifest_on_disk` says whether `dir` held a real `manifest.json`
/// (required for PJRT — its executables are on-disk artifacts). The
/// `SMOOTHCACHE_BACKEND` env var (`reference` | `pjrt`) overrides the
/// default choice.
pub fn select_backend(
    dir: &std::path::Path,
    manifest_on_disk: bool,
) -> Result<Box<dyn Backend>> {
    match backend_choice(manifest_on_disk)? {
        BackendChoice::Reference => Ok(Box::new(reference::ReferenceBackend::new())),
        BackendChoice::Pjrt => open_pjrt(dir, manifest_on_disk),
    }
}

/// Whether the backend [`select_backend`] would choose for this
/// configuration can be *replicated* — one independent instance per
/// executor thread in the coordinator's worker pool. The reference
/// backend replicates freely (pure host state, deterministic weight
/// synthesis); PJRT does not (thread-bound device handles, one device),
/// so the coordinator transparently degrades its pool to N = 1 there.
/// An invalid `SMOOTHCACHE_BACKEND` also degrades to 1: the executors'
/// own `select_backend` calls will surface the error.
pub fn backend_supports_replicas(_dir: &std::path::Path, manifest_on_disk: bool) -> bool {
    matches!(backend_choice(manifest_on_disk), Ok(BackendChoice::Reference))
}

#[cfg(feature = "pjrt")]
fn open_pjrt(dir: &std::path::Path, manifest_on_disk: bool) -> Result<Box<dyn Backend>> {
    if !manifest_on_disk {
        crate::bail!("pjrt backend needs an artifacts manifest in {dir:?} — run `make artifacts`");
    }
    Ok(Box::new(pjrt::PjrtBackend::open(dir.to_path_buf())?))
}

#[cfg(not(feature = "pjrt"))]
fn open_pjrt(_dir: &std::path::Path, _manifest_on_disk: bool) -> Result<Box<dyn Backend>> {
    Err(crate::err!(
        "this build has no PJRT support — rebuild with `--features pjrt` (see DESIGN.md)"
    ))
}
