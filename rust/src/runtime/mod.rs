//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Interchange is HLO **text** (jax ≥ 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Weights are uploaded once as device-resident [`xla::PjRtBuffer`]s and
//! passed by reference on every call (`execute_b`), so the request path
//! transfers only activations.
//!
//! PJRT handles are not `Send`/`Sync`; the engine owns them on a single
//! executor thread (coordinator threads talk to it over channels).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;

/// Host-side executable input (f32 tensor or i32 index array).
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn f32(t: Tensor) -> HostValue {
        HostValue::F32(t)
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostValue {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32 { shape, .. } => shape,
        }
    }
}

/// Cumulative runtime counters (perf pass + MAC/latency accounting).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_seconds: f64,
    pub uploads: u64,
    pub upload_seconds: f64,
    pub compiles: u64,
    pub compile_seconds: f64,
}

/// A compiled PJRT executable plus its interface metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub num_outputs: usize,
}

/// PJRT client + executable cache. One per executor thread.
pub struct Runtime {
    client: xla::PjRtClient,
    stats: std::cell::RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, stats: Default::default() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RuntimeStats::default();
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path, num_outputs: usize) -> Result<Executable> {
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        let mut s = self.stats.borrow_mut();
        s.compiles += 1;
        s.compile_seconds += t.elapsed().as_secs_f64();
        Ok(Executable {
            exe,
            name: path.file_name().unwrap().to_string_lossy().into_owned(),
            num_outputs,
        })
    }

    /// Upload a host value to a device-resident buffer.
    pub fn upload(&self, v: &HostValue) -> Result<xla::PjRtBuffer> {
        let t = Instant::now();
        let buf = match v {
            HostValue::F32(t) => self
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .map_err(|e| anyhow!("upload f32: {e:?}"))?,
            HostValue::I32 { shape, data } => self
                .client
                .buffer_from_host_buffer::<i32>(data, shape, None)
                .map_err(|e| anyhow!("upload i32: {e:?}"))?,
        };
        let mut s = self.stats.borrow_mut();
        s.uploads += 1;
        s.upload_seconds += t.elapsed().as_secs_f64();
        Ok(buf)
    }

    /// Execute with device-resident argument buffers; download all tuple
    /// outputs as f32 host tensors.
    pub fn execute(
        &self,
        exe: &Executable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let t = Instant::now();
        let out = exe
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", exe.name))?;
        let result = out
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("execute {}: empty result", exe.name))?;
        let lit = result
            .to_literal_sync()
            .map_err(|e| anyhow!("download {}: {e:?}", exe.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple {}: {e:?}", exe.name))?;
        if parts.len() != exe.num_outputs {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                exe.name,
                exe.num_outputs,
                parts.len()
            ));
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p
                .array_shape()
                .map_err(|e| anyhow!("shape {}: {e:?}", exe.name))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec {}: {e:?}", exe.name))?;
            tensors.push(Tensor::new(dims, data));
        }
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.exec_seconds += t.elapsed().as_secs_f64();
        Ok(tensors)
    }

    /// Convenience: upload host args then execute.
    pub fn execute_host(
        &self,
        exe: &Executable,
        host_args: &[HostValue],
        device_args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let uploaded: Vec<xla::PjRtBuffer> =
            host_args.iter().map(|v| self.upload(v)).collect::<Result<_>>()?;
        let mut all: Vec<&xla::PjRtBuffer> = uploaded.iter().collect();
        all.extend_from_slice(device_args);
        self.execute(exe, &all)
    }
}

/// Artifact registry: resolves (family, entry, batch) → compiled
/// executable, compiling lazily and caching the handle.
pub struct Registry {
    pub dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Registry {
    pub fn new(dir: PathBuf) -> Registry {
        Registry { dir, cache: Default::default() }
    }

    pub fn get(
        &self,
        rt: &Runtime,
        file: &str,
        num_outputs: usize,
    ) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        if !path.exists() {
            return Err(anyhow!(
                "artifact {file} not found in {:?} — run `make artifacts`",
                self.dir
            ));
        }
        let exe = std::rc::Rc::new(
            rt.load_hlo(&path, num_outputs)
                .with_context(|| format!("loading {file}"))?,
        );
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
