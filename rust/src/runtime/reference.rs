//! Pure-Rust CPU reference backend.
//!
//! A faithful port of the branch-decomposed DiT forward defined by
//! `python/compile/model.py` (the jnp oracle path), executed directly on
//! the host [`crate::tensor`] substrate — no PJRT, no artifacts, no
//! dependencies. Branches are computed at exactly SmoothCache's caching
//! granularity (gated pre-residual deltas), so every policy, schedule,
//! calibration pass, bench and serving flow exercises the same code
//! path it would under the PJRT backend.
//!
//! All matmuls — projections, FFN, per-head attention products — route
//! through [`crate::tensor::gemm`], the cache-blocked threadpool GEMM
//! (SIMD-dispatched, bitwise identical across kernels) whose results
//! are bitwise invariant to the configured thread count (`--threads` /
//! `SMOOTHCACHE_THREADS`), so caching decisions and calibration curves
//! never depend on parallelism.
//!
//! When the ambient [`crate::tensor::quant::ComputeMode`] is a reduced
//! mode (pinned per generation step from the request's `compute:`
//! knob), every *weight* matmul — projections, FFN, adaLN modulation —
//! switches to [`crate::tensor::quant::matmul_q`] over a per-store
//! cached [`crate::tensor::quant::QuantMat`]. Attention score/value
//! products stay f32: they multiply activations, not weights, and
//! weight-only quantization is the ladder this backend implements (see
//! docs/adr/006).
//!
//! Weights are synthesized deterministically per (family, tensor name)
//! with [`crate::util::rng::Rng`] when no `weights.bin` artifact exists
//! (mirroring `init_weights(adaln_zero=False)`: std 0.02 linears, unit
//! gate biases so untrained families still produce O(1) branch deltas
//! for calibration), which makes the whole offline stack reproducible
//! from seeds alone.

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use super::{Backend, EmbedOut, RuntimeStats, StepCtx};
use crate::model::manifest::{branch_weight_names, FamilyManifest};
use crate::model::weights::WeightStore;
use crate::model::Cond;
use crate::tensor::{gemm, quant, Tensor};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Step payload: host copies of the per-step conditioning.
struct RefStepCtx {
    c: Tensor,
    cond: Option<Tensor>,
}

pub struct ReferenceBackend {
    families: HashMap<String, WeightStore>,
    stats: RefCell<RuntimeStats>,
}

impl ReferenceBackend {
    pub fn new() -> ReferenceBackend {
        ReferenceBackend { families: HashMap::new(), stats: RefCell::new(RuntimeStats::default()) }
    }

    fn weights(&self, family: &str) -> Result<&WeightStore> {
        self.families
            .get(family)
            .ok_or_else(|| crate::err!("family {family:?} not loaded in reference backend"))
    }

    fn tick(&self, t0: Instant) {
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.exec_seconds += t0.elapsed().as_secs_f64();
    }

    fn step_payload<'a>(&self, ctx: &'a StepCtx) -> Result<&'a RefStepCtx> {
        ctx.payload::<RefStepCtx>()
            .ok_or_else(|| crate::err!("step ctx was not produced by the reference backend"))
    }
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> String {
        "reference".to_string()
    }

    fn load_family(&mut self, fm: &FamilyManifest, weights: WeightStore) -> Result<()> {
        // sanity: the forward below needs every branch-site tensor
        for (block, br) in fm.branch_sites() {
            for wn in branch_weight_names(&br) {
                let name = format!("blocks.{block}.{br}.{wn}");
                weights
                    .get(&name)
                    .map_err(|e| e.context(format!("reference backend: {}", fm.name)))?;
            }
        }
        self.families.insert(fm.name.clone(), weights);
        Ok(())
    }

    fn embed(&self, fm: &FamilyManifest, x: &Tensor, t: &[f32], cond: &Cond) -> Result<EmbedOut> {
        let t0 = Instant::now();
        let ws = self.weights(&fm.name)?;
        let b = x.dim0();
        if t.len() != b {
            crate::bail!("embed: t batch {} != x batch {b}", t.len());
        }
        let d = fm.hidden;
        let s = fm.seq_len;
        let pd = patch_dim(fm);

        // --- patchify to [B, S, pd] ------------------------------------
        let xp = patchify(fm, x)?;

        // --- tokens = xp @ patch_w + patch_b + pos ---------------------
        let pos = ws.get("embed.pos")?;
        let mut tokens = affine(ws, "embed.patch_w", Some("embed.patch_b"), &xp, b * s, pd)?;
        for bi in 0..b {
            for si in 0..s {
                for j in 0..d {
                    tokens[(bi * s + si) * d + j] += pos.data[si * d + j];
                }
            }
        }
        let tokens = Tensor::new(vec![b, s, d], tokens);

        // --- timestep embedding → c [B, D] -----------------------------
        let temb = timestep_embedding(t, fm.t_freq_dim);
        let h1 = affine(ws, "embed.temb_w1", Some("embed.temb_b1"), &temb, b, fm.t_freq_dim)?;
        let h1: Vec<f32> = h1.into_iter().map(silu).collect();
        let mut c = affine(ws, "embed.temb_w2", Some("embed.temb_b2"), &h1, b, d)?;

        // --- conditioning ---------------------------------------------
        let mut cond_tokens: Option<Tensor> = None;
        match cond {
            Cond::Label(labels) => {
                if fm.num_classes == 0 {
                    crate::bail!("family {} takes prompt conditioning, got a label", fm.name);
                }
                if labels.len() != b {
                    crate::bail!("label batch {} != x batch {b}", labels.len());
                }
                let emb = ws.get("embed.label_emb")?; // [classes+1, D]
                for (bi, &l) in labels.iter().enumerate() {
                    let l = l as usize;
                    if l > fm.num_classes {
                        crate::bail!("label {l} out of range (null class = {})", fm.num_classes);
                    }
                    for j in 0..d {
                        c[bi * d + j] += emb.data[l * d + j];
                    }
                }
            }
            Cond::Prompt(ids) => {
                if fm.vocab == 0 {
                    crate::bail!("family {} takes label conditioning, got a prompt", fm.name);
                }
                let sc = fm.cond_len;
                if ids.len() != b * sc {
                    crate::bail!("prompt ids {} != batch {b} × cond_len {sc}", ids.len());
                }
                let emb = ws.get("embed.prompt_emb")?; // [vocab, D]
                let mut ct = vec![0.0f32; b * sc * d];
                for bi in 0..b {
                    for si in 0..sc {
                        let id = ids[bi * sc + si] as usize;
                        if id >= fm.vocab {
                            crate::bail!("prompt id {id} out of vocab {}", fm.vocab);
                        }
                        ct[(bi * sc + si) * d..(bi * sc + si + 1) * d]
                            .copy_from_slice(&emb.data[id * d..(id + 1) * d]);
                    }
                }
                // c += mean over the conditioning axis
                for bi in 0..b {
                    for j in 0..d {
                        let mut m = 0.0f32;
                        for si in 0..sc {
                            m += ct[(bi * sc + si) * d + j];
                        }
                        c[bi * d + j] += m / sc as f32;
                    }
                }
                cond_tokens = Some(Tensor::new(vec![b, sc, d], ct));
            }
        }

        self.tick(t0);
        Ok(EmbedOut { tokens, c: Tensor::new(vec![b, d], c), cond: cond_tokens })
    }

    fn make_step_ctx(&self, embed: &EmbedOut) -> Result<StepCtx> {
        Ok(StepCtx::new(
            embed.tokens.dim0(),
            Box::new(RefStepCtx { c: embed.c.clone(), cond: embed.cond.clone() }),
        ))
    }

    fn branch(
        &self,
        fm: &FamilyManifest,
        block: usize,
        branch: &str,
        tokens: &Tensor,
        ctx: &StepCtx,
    ) -> Result<Tensor> {
        let t0 = Instant::now();
        let ws = self.weights(&fm.name)?;
        let sc = self.step_payload(ctx)?;
        let prefix = format!("blocks.{block}.{branch}.");
        let out = if fm.frames > 0 {
            video_branch(fm, ws, &prefix, branch, tokens, sc.cond.as_ref(), &sc.c)?
        } else {
            plain_branch(fm, ws, &prefix, branch, tokens, sc.cond.as_ref(), &sc.c)?
        };
        self.tick(t0);
        Ok(out)
    }

    fn final_head(&self, fm: &FamilyManifest, tokens: &Tensor, ctx: &StepCtx) -> Result<Tensor> {
        let t0 = Instant::now();
        let ws = self.weights(&fm.name)?;
        let sc = self.step_payload(ctx)?;
        let b = tokens.dim0();
        let d = fm.hidden;
        let s = fm.seq_len;
        let pd = patch_dim(fm);

        let parts = mod_params(&sc.c, b, d, ws, "final.mod_w", "final.mod_b", 2)?;
        let h = ln_modulate(tokens, b, s, d, &parts[0], &parts[1]);
        let y = affine(ws, "final.lin_w", Some("final.lin_b"), &h, b * s, d)?;
        let out = unpatchify(fm, &y, b, pd)?;
        self.tick(t0);
        Ok(out)
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    fn reset_stats(&self) {
        *self.stats.borrow_mut() = RuntimeStats::default();
    }
}

// ---------------------------------------------------------------------------
// Branch bodies (pre-residual, gated) — ports of model.py
// ---------------------------------------------------------------------------

/// Dispatch for flat-token families (image / audio).
fn plain_branch(
    fm: &FamilyManifest,
    ws: &WeightStore,
    prefix: &str,
    branch: &str,
    x: &Tensor,
    cond: Option<&Tensor>,
    c: &Tensor,
) -> Result<Tensor> {
    let b = x.dim0();
    let s = x.shape[1];
    if branch.ends_with("xattn") {
        let cond = cond.ok_or_else(|| crate::err!("{prefix}: cross-attention needs cond tokens"))?;
        branch_xattn(fm, ws, prefix, x, b, s, cond, c)
    } else if branch.ends_with("attn") {
        branch_attn(fm, ws, prefix, x, b, s, c)
    } else if branch.ends_with("ffn") {
        branch_ffn(fm, ws, prefix, x, b, s, c)
    } else {
        Err(crate::err!("unknown branch type {branch:?}"))
    }
}

/// Video factorisation: spatial branches attend within a frame, temporal
/// branches across frames at a fixed spatial site. Tokens stay flat
/// `[B, F·Ssp, D]`; the sub-batched view is materialised, the branch body
/// runs on it, and the delta is mapped back.
///
/// The repeated conditioning (`cs`/`conds`) is invariant across a solver
/// step; staging it in the step context instead of rebuilding per branch
/// call would save depth×branch_types copies per step.
fn video_branch(
    fm: &FamilyManifest,
    ws: &WeightStore,
    prefix: &str,
    branch: &str,
    x: &Tensor,
    cond: Option<&Tensor>,
    c: &Tensor,
) -> Result<Tensor> {
    let b = x.dim0();
    let d = fm.hidden;
    let f = fm.frames;
    let ssp = fm.spatial_tokens;
    if x.shape[1] != f * ssp {
        crate::bail!("video tokens: seq {} != frames {f} × spatial {ssp}", x.shape[1]);
    }
    let spatial = branch.starts_with("s_");
    if !spatial && !branch.starts_with("t_") {
        crate::bail!("video branch {branch:?} must be s_* or t_*");
    }

    // sub-batched tokens + repeated conditioning
    let (sub_b, sub_s, reps) = if spatial { (b * f, ssp, f) } else { (b * ssp, f, ssp) };
    let xs = if spatial {
        // [B, F*Ssp, D] -> [B*F, Ssp, D]: identical memory layout
        Tensor::new(vec![sub_b, sub_s, d], x.data.clone())
    } else {
        // [B, F*Ssp, D] -> [B*Ssp, F, D]
        let mut data = vec![0.0f32; x.data.len()];
        for bi in 0..b {
            for fi in 0..f {
                for sp in 0..ssp {
                    let src = ((bi * f + fi) * ssp + sp) * d;
                    let dst = ((bi * ssp + sp) * f + fi) * d;
                    data[dst..dst + d].copy_from_slice(&x.data[src..src + d]);
                }
            }
        }
        Tensor::new(vec![sub_b, sub_s, d], data)
    };
    let cs = repeat_rows(c, b, d, reps);
    let conds = match cond {
        Some(ct) => Some(repeat_seq_rows(ct, b, reps)),
        None => None,
    };

    let base = &branch[2..];
    let delta = plain_branch(fm, ws, prefix, base, &xs, conds.as_ref(), &cs)?;

    // map the delta back to the flat token layout
    if spatial {
        Ok(Tensor::new(vec![b, f * ssp, d], delta.data))
    } else {
        let mut data = vec![0.0f32; delta.data.len()];
        for bi in 0..b {
            for fi in 0..f {
                for sp in 0..ssp {
                    let src = ((bi * ssp + sp) * f + fi) * d;
                    let dst = ((bi * f + fi) * ssp + sp) * d;
                    data[dst..dst + d].copy_from_slice(&delta.data[src..src + d]);
                }
            }
        }
        Ok(Tensor::new(vec![b, f * ssp, d], data))
    }
}

/// Self-attention branch delta: gate · Attn(modulate(LN(x))).
fn branch_attn(
    fm: &FamilyManifest,
    ws: &WeightStore,
    prefix: &str,
    x: &Tensor,
    b: usize,
    s: usize,
    c: &Tensor,
) -> Result<Tensor> {
    let d = fm.hidden;
    let parts =
        mod_params(c, b, d, ws, &format!("{prefix}mod_w"), &format!("{prefix}mod_b"), 3)?;
    let h = ln_modulate(x, b, s, d, &parts[0], &parts[1]);
    let qkv = affine(
        ws,
        &format!("{prefix}qkv_w"),
        Some(&format!("{prefix}qkv_b")),
        &h,
        b * s,
        d,
    )?;
    // split [B*S, 3D] into q/k/v [B*S, D]
    let mut q = vec![0.0f32; b * s * d];
    let mut k = vec![0.0f32; b * s * d];
    let mut v = vec![0.0f32; b * s * d];
    for r in 0..b * s {
        q[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d..r * 3 * d + d]);
        k[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d + d..r * 3 * d + 2 * d]);
        v[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d]);
    }
    let o = attention(&q, &k, &v, b, s, s, d, fm.heads);
    let y = affine(ws, &format!("{prefix}o_w"), Some(&format!("{prefix}o_b")), &o, b * s, d)?;
    Ok(gate(y, b, s, d, &parts[2]))
}

/// Cross-attention branch delta over conditioning tokens.
fn branch_xattn(
    fm: &FamilyManifest,
    ws: &WeightStore,
    prefix: &str,
    x: &Tensor,
    b: usize,
    s: usize,
    cond: &Tensor,
    c: &Tensor,
) -> Result<Tensor> {
    let d = fm.hidden;
    let sc = cond.shape[1];
    if cond.dim0() != b {
        crate::bail!("{prefix}: cond batch {} != token batch {b}", cond.dim0());
    }
    let parts =
        mod_params(c, b, d, ws, &format!("{prefix}mod_w"), &format!("{prefix}mod_b"), 3)?;
    let h = ln_modulate(x, b, s, d, &parts[0], &parts[1]);
    let q = affine(ws, &format!("{prefix}q_w"), Some(&format!("{prefix}q_b")), &h, b * s, d)?;
    let kv = affine(
        ws,
        &format!("{prefix}kv_w"),
        Some(&format!("{prefix}kv_b")),
        &cond.data,
        b * sc,
        d,
    )?;
    let mut k = vec![0.0f32; b * sc * d];
    let mut v = vec![0.0f32; b * sc * d];
    for r in 0..b * sc {
        k[r * d..(r + 1) * d].copy_from_slice(&kv[r * 2 * d..r * 2 * d + d]);
        v[r * d..(r + 1) * d].copy_from_slice(&kv[r * 2 * d + d..r * 2 * d + 2 * d]);
    }
    let o = attention(&q, &k, &v, b, s, sc, d, fm.heads);
    let y = affine(ws, &format!("{prefix}o_w"), Some(&format!("{prefix}o_b")), &o, b * s, d)?;
    Ok(gate(y, b, s, d, &parts[2]))
}

/// Feed-forward branch delta: gate · MLP(modulate(LN(x))).
fn branch_ffn(
    fm: &FamilyManifest,
    ws: &WeightStore,
    prefix: &str,
    x: &Tensor,
    b: usize,
    s: usize,
    c: &Tensor,
) -> Result<Tensor> {
    let d = fm.hidden;
    let dff = d * fm.mlp_ratio;
    let parts =
        mod_params(c, b, d, ws, &format!("{prefix}mod_w"), &format!("{prefix}mod_b"), 3)?;
    let h = ln_modulate(x, b, s, d, &parts[0], &parts[1]);
    let mut h1 = affine(ws, &format!("{prefix}w1"), Some(&format!("{prefix}b1")), &h, b * s, d)?;
    for vme in h1.iter_mut() {
        *vme = gelu(*vme);
    }
    let y = affine(ws, &format!("{prefix}w2"), Some(&format!("{prefix}b2")), &h1, b * s, dff)?;
    Ok(gate(y, b, s, d, &parts[2]))
}

// ---------------------------------------------------------------------------
// Kernels (ports of python/compile/kernels/ref.py)
// ---------------------------------------------------------------------------

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// tanh-approximation GELU (the variant the Pallas kernel fuses).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// `y = x @ w + b` for the weight tensor named `wname` (`[din, dout]`)
/// with row-major `x` `[rows, din]`. The heavy lifting happens in
/// [`crate::tensor::gemm`] — cache-blocked and row-panel-parallel over
/// the shared compute pool, with f32 accumulation order (and therefore
/// results) bitwise independent of thread count and kernel choice.
///
/// This is the single seam every weight matmul passes through: when the
/// ambient compute mode is reduced, the weight is fetched as a cached
/// [`quant::QuantMat`] and the product runs through
/// [`quant::matmul_q`] instead (bias always stays f32).
fn affine(
    ws: &WeightStore,
    wname: &str,
    bname: Option<&str>,
    x: &[f32],
    rows: usize,
    din: usize,
) -> Result<Vec<f32>> {
    let w = ws.get(wname)?;
    if w.shape.len() != 2 || w.shape[0] != din {
        crate::bail!(
            "affine: weight {wname:?} shape {:?} incompatible with input dim {din}",
            w.shape
        );
    }
    let dout = w.shape[1];
    if x.len() != rows * din {
        crate::bail!("affine: input len {} != rows {rows} × din {din}", x.len());
    }
    let bias_t = match bname {
        Some(bn) => Some(ws.get(bn)?),
        None => None,
    };
    let bias = bias_t.map(|t| t.data.as_slice());
    let mode = quant::compute_mode();
    if mode.is_reduced() {
        let q = ws.get_quant(wname, mode)?;
        return Ok(quant::matmul_q(x, rows, din, &q, bias));
    }
    Ok(gemm::matmul(x, rows, din, &w.data, dout, bias))
}

/// adaLN parameters: `silu(c) @ mod_w + mod_b` split into `n` chunks of
/// width D. Returns `n` buffers of `[B, D]`.
fn mod_params(
    c: &Tensor,
    b: usize,
    d: usize,
    ws: &WeightStore,
    mod_w: &str,
    mod_b: &str,
    n: usize,
) -> Result<Vec<Vec<f32>>> {
    let sc: Vec<f32> = c.data.iter().map(|&x| silu(x)).collect();
    let p = affine(ws, mod_w, Some(mod_b), &sc, b, d)?; // [B, n*D]
    let mut parts = vec![vec![0.0f32; b * d]; n];
    for bi in 0..b {
        for (j, part) in parts.iter_mut().enumerate() {
            part[bi * d..(bi + 1) * d]
                .copy_from_slice(&p[bi * n * d + j * d..bi * n * d + (j + 1) * d]);
        }
    }
    Ok(parts)
}

/// adaLN modulation: `(1 + scale) · LN(x) + shift` with LN over the
/// trailing axis (no learned affine), shift/scale `[B, D]` broadcast
/// over the sequence. Returns a flat `[B*S, D]` buffer.
fn ln_modulate(x: &Tensor, b: usize, s: usize, d: usize, shift: &[f32], scale: &[f32]) -> Vec<f32> {
    const EPS: f64 = 1e-6;
    let mut out = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for si in 0..s {
            let row = &x.data[(bi * s + si) * d..(bi * s + si + 1) * d];
            let mut mean = 0.0f64;
            for &v in row {
                mean += v as f64;
            }
            mean /= d as f64;
            let mut var = 0.0f64;
            for &v in row {
                let dv = v as f64 - mean;
                var += dv * dv;
            }
            var /= d as f64;
            let rstd = 1.0 / (var + EPS).sqrt();
            let orow = &mut out[(bi * s + si) * d..(bi * s + si + 1) * d];
            for j in 0..d {
                let ln = ((row[j] as f64 - mean) * rstd) as f32;
                orow[j] = ln * (1.0 + scale[bi * d + j]) + shift[bi * d + j];
            }
        }
    }
    out
}

/// adaLN-zero gating: `y · g` with `g` `[B, D]` broadcast over the
/// sequence axis. Consumes the flat `[B*S, D]` buffer, returns a tensor.
fn gate(mut y: Vec<f32>, b: usize, s: usize, d: usize, g: &[f32]) -> Tensor {
    for bi in 0..b {
        for si in 0..s {
            let row = &mut y[(bi * s + si) * d..(bi * s + si + 1) * d];
            for j in 0..d {
                row[j] *= g[bi * d + j];
            }
        }
    }
    Tensor::new(vec![b, s, d], y)
}

/// Multi-head scaled dot-product attention. `q` is `[B, Sq, D]`, `k`/`v`
/// are `[B, Sk, D]` (flat row-major buffers), heads split the trailing
/// dim. Softmax in f32 with max-subtraction (the numerically-stable
/// contract the Pallas kernel also honours). Returns `[B, Sq, D]`.
///
/// Each `(batch, head)` panel is gathered contiguous and its score
/// (`Qh @ Kh^T`) and value (`P @ Vh`) products routed through
/// [`crate::tensor::gemm`]; panels fan out over the shared compute pool
/// when large enough to pay for dispatch. Per-element accumulation
/// order is identical to the serial triple loop, so outputs are bitwise
/// invariant to the thread count.
fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    sq: usize,
    sk: usize,
    d: usize,
    heads: usize,
) -> Vec<f32> {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();

    // one job per (batch, head) panel; returns the contiguous [Sq, dh]
    // head output to scatter back into the interleaved layout
    let head_out = |bh: usize| -> Vec<f32> {
        let bi = bh / heads;
        let off = (bh % heads) * dh;
        let gather = |src: &[f32], s: usize| -> Vec<f32> {
            let mut panel = vec![0.0f32; s * dh];
            for si in 0..s {
                let base = (bi * s + si) * d + off;
                panel[si * dh..(si + 1) * dh].copy_from_slice(&src[base..base + dh]);
            }
            panel
        };
        let qh = gather(q, sq);
        let kh = gather(k, sk);
        let vh = gather(v, sk);
        // scores[Sq, Sk] = Qh @ Kh^T (transposed-B: Kh stays [Sk, dh])
        let mut scores = gemm::matmul_bt(&qh, sq, dh, &kh, sk, None);
        for qi in 0..sq {
            let row = &mut scores[qi * sk..(qi + 1) * sk];
            let mut max = f32::NEG_INFINITY;
            for sv in row.iter_mut() {
                *sv *= scale;
                if *sv > max {
                    max = *sv;
                }
            }
            let mut denom = 0.0f32;
            for sv in row.iter_mut() {
                *sv = (*sv - max).exp();
                denom += *sv;
            }
            let inv = 1.0 / denom;
            for sv in row.iter_mut() {
                *sv *= inv;
            }
        }
        // [Sq, dh] = P @ Vh — attention products stay f32 in every
        // compute mode (activations, not weights)
        gemm::matmul(&scores, sq, sk, &vh, dh, None)
    };

    let items: Vec<usize> = (0..b * heads).collect();
    // tiny panels (video temporal slices) aren't worth a channel round
    // trip per job; the math is identical either way. (The serial branch
    // still pays the per-head gather allocations — acceptable churn to
    // keep one code path whose numerics are bitwise-shared with the
    // parallel branch.)
    let outs: Vec<Vec<f32>> = if sq * sk * dh >= 16 * 1024 {
        gemm::parallel_over(items, &head_out)
    } else {
        items.into_iter().map(&head_out).collect()
    };

    let mut out = vec![0.0f32; b * sq * d];
    for (bh, ho) in outs.iter().enumerate() {
        let bi = bh / heads;
        let off = (bh % heads) * dh;
        for qi in 0..sq {
            let base = (bi * sq + qi) * d + off;
            out[base..base + dh].copy_from_slice(&ho[qi * dh..(qi + 1) * dh]);
        }
    }
    out
}

/// Sinusoidal embedding of continuous t (scaled to [0, 1000]):
/// `[cos(args) ‖ sin(args)]`, args = 1000·t·exp(−ln 10⁴·i/half).
fn timestep_embedding(t: &[f32], freq_dim: usize) -> Vec<f32> {
    let half = freq_dim / 2;
    let mut out = vec![0.0f32; t.len() * freq_dim];
    for (bi, &tv) in t.iter().enumerate() {
        for i in 0..half {
            let freq = (-(10000.0f64.ln()) * i as f64 / half as f64).exp();
            let arg = (tv as f64) * 1000.0 * freq;
            out[bi * freq_dim + i] = arg.cos() as f32;
            out[bi * freq_dim + half + i] = arg.sin() as f32;
        }
    }
    out
}

/// Per-sample flattened patch width.
pub fn patch_dim(fm: &FamilyManifest) -> usize {
    fm.latent_size() / fm.seq_len
}

/// Repeat each row of a `[B, D]` tensor `reps` times consecutively
/// (`jnp.repeat(c, reps, axis=0)`): `[B·reps, D]`.
fn repeat_rows(c: &Tensor, b: usize, d: usize, reps: usize) -> Tensor {
    let mut data = Vec::with_capacity(b * reps * d);
    for bi in 0..b {
        for _ in 0..reps {
            data.extend_from_slice(&c.data[bi * d..(bi + 1) * d]);
        }
    }
    Tensor::new(vec![b * reps, d], data)
}

/// Repeat each `[Sc, D]` sample of a `[B, Sc, D]` tensor `reps` times.
fn repeat_seq_rows(ct: &Tensor, b: usize, reps: usize) -> Tensor {
    let stride = ct.stride0();
    let mut data = Vec::with_capacity(b * reps * stride);
    for bi in 0..b {
        for _ in 0..reps {
            data.extend_from_slice(&ct.data[bi * stride..(bi + 1) * stride]);
        }
    }
    let mut shape = ct.shape.clone();
    shape[0] = b * reps;
    Tensor::new(shape, data)
}

/// Patchify the latent into `[B, S, pd]` (flat buffer), mirroring
/// model.py's reshape/transpose per family kind (by latent rank:
/// 3 = image H·W·C, 2 = audio T·C pass-through, 4 = video F·H·W·C).
fn patchify(fm: &FamilyManifest, x: &Tensor) -> Result<Vec<f32>> {
    let b = x.dim0();
    let p = fm.patch.max(1);
    let mut expect = vec![b];
    expect.extend(&fm.latent_shape);
    if x.shape != expect {
        crate::bail!("latent shape {:?} != expected {:?}", x.shape, expect);
    }
    match fm.latent_shape.len() {
        2 => Ok(x.data.clone()), // [B, T, C] already tokens
        3 => {
            let (hh, ww, ch) = (fm.latent_shape[0], fm.latent_shape[1], fm.latent_shape[2]);
            let (gh, gw) = (hh / p, ww / p);
            let pd = p * p * ch;
            let mut out = vec![0.0f32; b * gh * gw * pd];
            for bi in 0..b {
                for gi in 0..gh {
                    for gj in 0..gw {
                        let tok = gi * gw + gj;
                        for pi in 0..p {
                            for pj in 0..p {
                                let src = ((bi * hh + gi * p + pi) * ww + gj * p + pj) * ch;
                                let dst = (bi * gh * gw + tok) * pd + (pi * p + pj) * ch;
                                out[dst..dst + ch].copy_from_slice(&x.data[src..src + ch]);
                            }
                        }
                    }
                }
            }
            Ok(out)
        }
        4 => {
            let (ff, hh, ww, ch) = (
                fm.latent_shape[0],
                fm.latent_shape[1],
                fm.latent_shape[2],
                fm.latent_shape[3],
            );
            let (gh, gw) = (hh / p, ww / p);
            let pd = p * p * ch;
            let toks = ff * gh * gw;
            let mut out = vec![0.0f32; b * toks * pd];
            for bi in 0..b {
                for fi in 0..ff {
                    for gi in 0..gh {
                        for gj in 0..gw {
                            let tok = fi * gh * gw + gi * gw + gj;
                            for pi in 0..p {
                                for pj in 0..p {
                                    let src = (((bi * ff + fi) * hh + gi * p + pi) * ww
                                        + gj * p
                                        + pj)
                                        * ch;
                                    let dst = (bi * toks + tok) * pd + (pi * p + pj) * ch;
                                    out[dst..dst + ch].copy_from_slice(&x.data[src..src + ch]);
                                }
                            }
                        }
                    }
                }
            }
            Ok(out)
        }
        r => Err(crate::err!("unsupported latent rank {r}")),
    }
}

/// Inverse of [`patchify`]: `[B, S, pd]` head output back to the latent
/// shape.
fn unpatchify(fm: &FamilyManifest, y: &[f32], b: usize, pd: usize) -> Result<Tensor> {
    let p = fm.patch.max(1);
    let mut shape = vec![b];
    shape.extend(&fm.latent_shape);
    match fm.latent_shape.len() {
        2 => Ok(Tensor::new(shape, y.to_vec())),
        3 => {
            let (hh, ww, ch) = (fm.latent_shape[0], fm.latent_shape[1], fm.latent_shape[2]);
            let (gh, gw) = (hh / p, ww / p);
            let mut out = vec![0.0f32; b * hh * ww * ch];
            for bi in 0..b {
                for gi in 0..gh {
                    for gj in 0..gw {
                        let tok = gi * gw + gj;
                        for pi in 0..p {
                            for pj in 0..p {
                                let dst = ((bi * hh + gi * p + pi) * ww + gj * p + pj) * ch;
                                let src = (bi * gh * gw + tok) * pd + (pi * p + pj) * ch;
                                out[dst..dst + ch].copy_from_slice(&y[src..src + ch]);
                            }
                        }
                    }
                }
            }
            Ok(Tensor::new(shape, out))
        }
        4 => {
            let (ff, hh, ww, ch) = (
                fm.latent_shape[0],
                fm.latent_shape[1],
                fm.latent_shape[2],
                fm.latent_shape[3],
            );
            let (gh, gw) = (hh / p, ww / p);
            let toks = ff * gh * gw;
            let mut out = vec![0.0f32; b * ff * hh * ww * ch];
            for bi in 0..b {
                for fi in 0..ff {
                    for gi in 0..gh {
                        for gj in 0..gw {
                            let tok = fi * gh * gw + gi * gw + gj;
                            for pi in 0..p {
                                for pj in 0..p {
                                    let dst = (((bi * ff + fi) * hh + gi * p + pi) * ww
                                        + gj * p
                                        + pj)
                                        * ch;
                                    let src = (bi * toks + tok) * pd + (pi * p + pj) * ch;
                                    out[dst..dst + ch].copy_from_slice(&y[src..src + ch]);
                                }
                            }
                        }
                    }
                }
            }
            Ok(Tensor::new(shape, out))
        }
        r => Err(crate::err!("unsupported latent rank {r}")),
    }
}

// ---------------------------------------------------------------------------
// Deterministic weight synthesis (port of init_weights, adaln_zero=False)
// ---------------------------------------------------------------------------

/// FNV-1a over (family, tensor name): every tensor gets an independent,
/// order-insensitive stream.
fn tensor_seed(seed: u64, family: &str, name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ seed;
    for &byte in family.as_bytes().iter().chain(b"/").chain(name.as_bytes()) {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Synthesize the full weight set for a family, deterministically from
/// `seed`. Layout and inits mirror `model.init_weights` with
/// `adaln_zero=False`: std-0.02 linears, std-0.5 embeddings, fixed
/// sin-cos positional table, zero biases except the unit gate bias.
pub fn synth_weights(fm: &FamilyManifest, seed: u64) -> WeightStore {
    let d = fm.hidden;
    let dff = d * fm.mlp_ratio;
    let pd = patch_dim(fm);
    let mut ws = WeightStore::new();

    let lin = |name: &str, shape: Vec<usize>, std: f32| -> Tensor {
        let mut rng = Rng::new(tensor_seed(seed, &fm.name, name));
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * std).collect();
        Tensor::new(shape, data)
    };

    ws.insert("embed.patch_w", lin("embed.patch_w", vec![pd, d], 0.02));
    ws.insert("embed.patch_b", Tensor::zeros(vec![d]));
    ws.insert("embed.pos", sincos_pos(fm.seq_len, d));
    ws.insert("embed.temb_w1", lin("embed.temb_w1", vec![fm.t_freq_dim, d], 0.02));
    ws.insert("embed.temb_b1", Tensor::zeros(vec![d]));
    ws.insert("embed.temb_w2", lin("embed.temb_w2", vec![d, d], 0.02));
    ws.insert("embed.temb_b2", Tensor::zeros(vec![d]));
    if fm.num_classes > 0 {
        ws.insert(
            "embed.label_emb",
            lin("embed.label_emb", vec![fm.num_classes + 1, d], 0.5),
        );
    }
    if fm.vocab > 0 {
        ws.insert("embed.prompt_emb", lin("embed.prompt_emb", vec![fm.vocab, d], 0.5));
    }

    for i in 0..fm.depth {
        for br in &fm.branch_types {
            let pre = format!("blocks.{i}.{br}.");
            let name = |suffix: &str| format!("{pre}{suffix}");
            ws.insert(name("mod_w"), lin(&name("mod_w"), vec![d, 3 * d], 0.02));
            // unit gate bias: untrained families behave like standard
            // pre-LN transformers, so caching perturbations are material
            let mut mod_b = vec![0.0f32; 3 * d];
            for g in &mut mod_b[2 * d..] {
                *g = 1.0;
            }
            ws.insert(name("mod_b"), Tensor::new(vec![3 * d], mod_b));
            if br.ends_with("xattn") {
                ws.insert(name("q_w"), lin(&name("q_w"), vec![d, d], 0.02));
                ws.insert(name("q_b"), Tensor::zeros(vec![d]));
                ws.insert(name("kv_w"), lin(&name("kv_w"), vec![d, 2 * d], 0.02));
                ws.insert(name("kv_b"), Tensor::zeros(vec![2 * d]));
                ws.insert(name("o_w"), lin(&name("o_w"), vec![d, d], 0.02));
                ws.insert(name("o_b"), Tensor::zeros(vec![d]));
            } else if br.ends_with("attn") {
                ws.insert(name("qkv_w"), lin(&name("qkv_w"), vec![d, 3 * d], 0.02));
                ws.insert(name("qkv_b"), Tensor::zeros(vec![3 * d]));
                ws.insert(name("o_w"), lin(&name("o_w"), vec![d, d], 0.02));
                ws.insert(name("o_b"), Tensor::zeros(vec![d]));
            } else {
                ws.insert(name("w1"), lin(&name("w1"), vec![d, dff], 0.02));
                ws.insert(name("b1"), Tensor::zeros(vec![dff]));
                ws.insert(name("w2"), lin(&name("w2"), vec![dff, d], 0.02));
                ws.insert(name("b2"), Tensor::zeros(vec![d]));
            }
        }
    }

    ws.insert("final.mod_w", lin("final.mod_w", vec![d, 2 * d], 0.02));
    ws.insert("final.mod_b", Tensor::zeros(vec![2 * d]));
    ws.insert("final.lin_w", lin("final.lin_w", vec![d, pd], 0.02));
    ws.insert("final.lin_b", Tensor::zeros(vec![pd]));
    ws
}

/// Fixed sin-cos positional embedding over the flat token axis:
/// `[sin(pos·div) ‖ cos(pos·div)]`, `div = exp(−ln 10⁴·i/(D/2))`.
fn sincos_pos(s: usize, d: usize) -> Tensor {
    let half = d / 2;
    let mut data = vec![0.0f32; s * d];
    for pos in 0..s {
        for i in 0..half {
            let div = (-(10000.0f64.ln()) * i as f64 / half as f64).exp();
            let ang = pos as f64 * div;
            data[pos * d + i] = ang.sin() as f32;
            data[pos * d + half + i] = ang.cos() as f32;
        }
    }
    Tensor::new(vec![s, d], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;

    fn image_fm() -> FamilyManifest {
        Manifest::builtin().family("image").unwrap().clone()
    }

    fn video_fm() -> FamilyManifest {
        Manifest::builtin().family("video").unwrap().clone()
    }

    fn loaded_backend(fm: &FamilyManifest) -> ReferenceBackend {
        let mut be = ReferenceBackend::new();
        be.load_family(fm, synth_weights(fm, 0)).unwrap();
        be
    }

    #[test]
    fn synth_weights_are_deterministic_and_complete() {
        let fm = image_fm();
        let a = synth_weights(&fm, 0);
        let b = synth_weights(&fm, 0);
        assert_eq!(a.len(), b.len());
        for name in a.names() {
            assert_eq!(a.get(name).unwrap(), b.get(name).unwrap(), "{name}");
        }
        // different seed actually changes the linears
        let c = synth_weights(&fm, 1);
        assert_ne!(
            a.get("embed.patch_w").unwrap().data,
            c.get("embed.patch_w").unwrap().data
        );
    }

    #[test]
    fn patchify_roundtrips_through_unpatchify() {
        for fm in [image_fm(), video_fm()] {
            let mut rng = Rng::new(3);
            let mut shape = vec![2usize];
            shape.extend(&fm.latent_shape);
            let x = Tensor::randn(shape, &mut rng);
            let xp = patchify(&fm, &x).unwrap();
            let back = unpatchify(&fm, &xp, 2, patch_dim(&fm)).unwrap();
            assert_eq!(back, x, "{}", fm.name);
        }
    }

    #[test]
    fn attention_rows_sum_preserved_for_uniform_values() {
        // with constant V, attention output equals that constant
        let (b, s, d, heads) = (1usize, 4usize, 8usize, 2usize);
        let mut rng = Rng::new(9);
        let q: Vec<f32> = (0..b * s * d).map(|_| rng.normal_f32()).collect();
        let k: Vec<f32> = (0..b * s * d).map(|_| rng.normal_f32()).collect();
        let v = vec![2.5f32; b * s * d];
        let o = attention(&q, &k, &v, b, s, s, d, heads);
        for val in o {
            assert!((val - 2.5).abs() < 1e-5, "{val}");
        }
    }

    #[test]
    fn embed_shapes_and_determinism() {
        let fm = image_fm();
        let be = loaded_backend(&fm);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(vec![2, 16, 16, 4], &mut rng);
        let cond = Cond::Label(vec![1, 4]);
        let e1 = be.embed(&fm, &x, &[0.5, 0.25], &cond).unwrap();
        assert_eq!(e1.tokens.shape, vec![2, 64, 128]);
        assert_eq!(e1.c.shape, vec![2, 128]);
        assert!(e1.cond.is_none());
        let e2 = be.embed(&fm, &x, &[0.5, 0.25], &cond).unwrap();
        assert_eq!(e1.tokens, e2.tokens);
        assert_eq!(e1.c, e2.c);
    }

    #[test]
    fn branch_deltas_have_token_shape_and_depend_on_block() {
        let fm = image_fm();
        let be = loaded_backend(&fm);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        let emb = be.embed(&fm, &x, &[0.7], &Cond::Label(vec![0])).unwrap();
        let ctx = be.make_step_ctx(&emb).unwrap();
        let d0 = be.branch(&fm, 0, "attn", &emb.tokens, &ctx).unwrap();
        let d1 = be.branch(&fm, 1, "attn", &emb.tokens, &ctx).unwrap();
        assert_eq!(d0.shape, emb.tokens.shape);
        assert_ne!(d0.data, d1.data, "different blocks must use different weights");
        // gated deltas of an untrained family are O(1), not degenerate
        assert!(d0.max_abs() > 1e-4);
        assert!(d0.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn video_spatial_and_temporal_branches_differ() {
        let fm = video_fm();
        let be = loaded_backend(&fm);
        let mut rng = Rng::new(4);
        let x = Tensor::randn(vec![1, 4, 8, 8, 4], &mut rng);
        let cond = Cond::Prompt(vec![7; fm.cond_len]);
        let emb = be.embed(&fm, &x, &[0.9], &cond).unwrap();
        assert!(emb.cond.is_some());
        let ctx = be.make_step_ctx(&emb).unwrap();
        let ds = be.branch(&fm, 0, "s_attn", &emb.tokens, &ctx).unwrap();
        let dt = be.branch(&fm, 0, "t_attn", &emb.tokens, &ctx).unwrap();
        assert_eq!(ds.shape, emb.tokens.shape);
        assert_eq!(dt.shape, emb.tokens.shape);
        assert_ne!(ds.data, dt.data);
        let dx = be.branch(&fm, 0, "s_xattn", &emb.tokens, &ctx).unwrap();
        assert!(dx.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn final_head_returns_latent_shape() {
        let fm = image_fm();
        let be = loaded_backend(&fm);
        let mut rng = Rng::new(5);
        let x = Tensor::randn(vec![2, 16, 16, 4], &mut rng);
        let emb = be.embed(&fm, &x, &[0.3, 0.3], &Cond::Label(vec![2, 3])).unwrap();
        let ctx = be.make_step_ctx(&emb).unwrap();
        let eps = be.final_head(&fm, &emb.tokens, &ctx).unwrap();
        assert_eq!(eps.shape, vec![2, 16, 16, 4]);
        let st = be.stats();
        assert!(st.executions >= 2);
    }

    #[test]
    fn reduced_compute_modes_perturb_but_track_the_f32_branch() {
        let fm = image_fm();
        let be = loaded_backend(&fm);
        let mut rng = Rng::new(6);
        let x = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        let emb = be.embed(&fm, &x, &[0.6], &Cond::Label(vec![1])).unwrap();
        let ctx = be.make_step_ctx(&emb).unwrap();
        let f32_out = be.branch(&fm, 0, "ffn", &emb.tokens, &ctx).unwrap();
        for mode in quant::ComputeMode::REDUCED {
            let a = quant::with_compute(mode, || be.branch(&fm, 0, "ffn", &emb.tokens, &ctx))
                .unwrap();
            let b = quant::with_compute(mode, || be.branch(&fm, 0, "ffn", &emb.tokens, &ctx))
                .unwrap();
            assert_eq!(a, b, "{} branch must be deterministic", mode.name());
            assert_ne!(a.data, f32_out.data, "{} must actually re-encode weights", mode.name());
            let scale = f32_out.max_abs().max(1e-6);
            let max_err = a
                .data
                .iter()
                .zip(&f32_out.data)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err / scale < 0.1,
                "{}: branch drifted {max_err} (scale {scale})",
                mode.name()
            );
        }
        // back outside the scope the mode is f32 again
        let again = be.branch(&fm, 0, "ffn", &emb.tokens, &ctx).unwrap();
        assert_eq!(again, f32_out);
    }

    #[test]
    fn gelu_and_silu_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(1.0) - 0.731058).abs() < 1e-4);
    }
}
