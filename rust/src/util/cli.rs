//! Declarative CLI flag parser (substrate; no clap offline).
//!
//! Supports `--name value`, `--name=value`, boolean `--flag`, and a
//! leading positional subcommand. Generates usage text from the specs.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_bool: bool,
}

#[derive(Default)]
pub struct CliSpec {
    pub command: String,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

impl CliSpec {
    pub fn new(command: &str, about: &'static str) -> CliSpec {
        CliSpec { command: command.to_string(), about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(Flag {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    pub fn req_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, is_bool: false });
        self
    }

    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag {
            name,
            help,
            default: Some("false".into()),
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.command, self.about);
        for f in &self.flags {
            let d = match (&f.default, f.is_bool) {
                (_, true) => String::new(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    pub fn parse(&self, args: &[String]) -> Result<ParsedArgs, String> {
        let mut vals: BTreeMap<String, String> = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                vals.insert(f.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            let Some(stripped) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {a:?}\n\n{}", self.usage()));
            };
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let Some(flag) = self.flags.iter().find(|f| f.name == name) else {
                return Err(format!("unknown flag --{name}\n\n{}", self.usage()));
            };
            let val = if let Some(v) = inline_val {
                v
            } else if flag.is_bool {
                "true".to_string()
            } else {
                i += 1;
                args.get(i).cloned().ok_or(format!("--{name} needs a value"))?
            };
            vals.insert(name.to_string(), val);
            i += 1;
        }
        for f in &self.flags {
            if !vals.contains_key(f.name) {
                return Err(format!("missing required flag --{}\n\n{}", f.name, self.usage()));
            }
        }
        Ok(ParsedArgs { vals })
    }
}

#[derive(Debug)]
pub struct ParsedArgs {
    vals: BTreeMap<String, String>,
}

impl ParsedArgs {
    pub fn str(&self, name: &str) -> &str {
        self.vals.get(name).map(|s| s.as_str()).unwrap_or_else(|| panic!("flag {name} not in spec"))
    }

    pub fn string(&self, name: &str) -> String {
        self.str(name).to_string()
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected integer, got {:?}", self.str(name)))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected integer, got {:?}", self.str(name)))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected number, got {:?}", self.str(name)))
    }

    pub fn bool(&self, name: &str) -> bool {
        self.str(name) == "true"
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        let s = self.str(name);
        if s.is_empty() {
            vec![]
        } else {
            s.split(',').map(|p| p.trim().to_string()).collect()
        }
    }

    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>, String> {
        self.list(name)
            .iter()
            .map(|s| s.parse().map_err(|_| format!("--{name}: bad number {s:?}")))
            .collect()
    }

    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, String> {
        self.list(name)
            .iter()
            .map(|s| s.parse().map_err(|_| format!("--{name}: bad integer {s:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CliSpec {
        CliSpec::new("test", "a test command")
            .flag("alpha", "0.1", "threshold")
            .req_flag("family", "model family")
            .bool_flag("verbose", "log more")
    }

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_required() {
        let a = spec().parse(&argv(&["--family", "image"])).unwrap();
        assert_eq!(a.str("alpha"), "0.1");
        assert_eq!(a.f64("alpha").unwrap(), 0.1);
        assert_eq!(a.str("family"), "image");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_bools() {
        let a = spec().parse(&argv(&["--family=audio", "--alpha=0.3", "--verbose"])).unwrap();
        assert_eq!(a.str("family"), "audio");
        assert_eq!(a.f64("alpha").unwrap(), 0.3);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&argv(&["--alpha", "0.2"])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(spec().parse(&argv(&["--family", "x", "--nope", "1"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = spec().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--alpha"));
        assert!(err.contains("threshold"));
    }

    #[test]
    fn lists_parse() {
        let s = CliSpec::new("t", "x").flag("steps", "30,50,70", "steps");
        let a = s.parse(&[]).unwrap();
        assert_eq!(a.usize_list("steps").unwrap(), vec![30, 50, 70]);
    }
}
