//! Machine-readable benchmark reports: the `BENCH_<area>.json`
//! trajectory layer (docs/benchmarks.md, ADR-005).
//!
//! Every bench target serialises one [`BenchReport`] — run metadata
//! (policy, family, solver, steps, threads, workers, smoke) plus a flat
//! list of named [`Metric`]s — through `util::json`, so the repo's
//! performance claims (throughput, queue-wait vs execute decomposition,
//! step_mean, plan hit-rate, speedup-vs-no-cache, quality scores) are
//! diffable artifacts instead of human tables. [`diff`] compares two
//! reports under per-metric tolerance thresholds and backs the
//! `bench_diff` binary that gates `scripts/verify.sh` and CI against
//! the committed `BENCH_baseline/` snapshot.
//!
//! Invariants enforced loudly (tests/bench_report.rs):
//! * metric values and tolerances are finite — NaN/inf are rejected at
//!   insert, at save, and at load (JSON `null` never round-trips into
//!   a silent 0);
//! * metric names are unique within a report;
//! * a diff treats a metric present in the baseline but missing from
//!   the candidate as a hard error, never a silent pass.

use super::Table;
use crate::util::error::Result;
use crate::util::json::{parse, Json};

/// Schema tag written into every report file; [`BenchReport::from_json`]
/// rejects anything else so format drift fails loudly.
pub const SCHEMA: &str = "smoothcache-bench/v1";

/// One named measurement inside a [`BenchReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    /// Stable identifier, unique within the report. Convention:
    /// `scope/stat` (e.g. `fora:2/throughput_rps`) — names are matched
    /// exactly by [`diff`], so keep them independent of run-derived
    /// values like calibrated alphas.
    pub name: String,
    /// Finite measurement value (enforced by [`BenchReport::push`]).
    pub value: f64,
    /// Human-readable unit (`req/s`, `us`, `%`, `x`, `score`, …).
    pub unit: String,
    /// Direction: `true` when larger is better (throughput, PSNR),
    /// `false` when smaller is better (latency, FFD, LPIPS).
    pub higher_is_better: bool,
    /// Optional per-metric gate tolerance in percent, overriding the
    /// diff-wide default. Benches set this wide for wall-clock metrics
    /// (machine-dependent) and tight for deterministic ones (skip
    /// fractions, GMACs, quality scores — bitwise thread-invariant).
    pub tol_pct: Option<f64>,
}

/// A machine-readable bench run: area + run metadata + metrics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BenchReport {
    /// Which bench produced this (`engine`, `serving`, `table1_image`, …).
    pub area: String,
    /// Ordered run-metadata pairs (family, solver, steps, threads,
    /// workers, policy roster, smoke…), all stringly so the schema
    /// stays flat.
    pub meta: Vec<(String, String)>,
    /// The measurements, in insertion order.
    pub metrics: Vec<Metric>,
}

impl BenchReport {
    /// Empty report for `area`.
    pub fn new(area: &str) -> BenchReport {
        BenchReport { area: area.to_string(), meta: Vec::new(), metrics: Vec::new() }
    }

    /// Append a run-metadata pair (last write wins on duplicate keys at
    /// read time; benches write each key once).
    pub fn meta(&mut self, key: &str, value: impl std::fmt::Display) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Append the standard run-environment keys every `BENCH_*.json`
    /// emitter records so a report is interpretable without the shell
    /// history that produced it: `run_threads` (GEMM pool size),
    /// `run_kernel` (dispatched microkernel), `run_compute` (ambient
    /// weight-matmul mode on the calling thread), and `run_workers`
    /// (executor replicas; pass 0 for benches that drive the engine
    /// directly). Metadata is informational — the regression gate only
    /// compares metrics.
    pub fn run_meta(&mut self, workers: usize) {
        self.meta("run_threads", crate::tensor::gemm::threads());
        self.meta("run_kernel", crate::tensor::gemm::active_kernel_name());
        self.meta("run_compute", crate::tensor::quant::compute_mode().name());
        self.meta("run_workers", workers);
    }

    /// Append a metric, rejecting non-finite values, non-finite or
    /// negative tolerances, and duplicate names.
    pub fn push(&mut self, m: Metric) -> Result<()> {
        crate::ensure!(
            m.value.is_finite(),
            "metric {:?}: non-finite value {} (NaN/inf cannot enter a bench report)",
            m.name,
            m.value
        );
        if let Some(t) = m.tol_pct {
            crate::ensure!(
                t.is_finite() && t >= 0.0,
                "metric {:?}: invalid tolerance {t} (must be finite and >= 0)",
                m.name
            );
        }
        crate::ensure!(!m.name.is_empty(), "metric with empty name");
        crate::ensure!(
            self.get(&m.name).is_none(),
            "duplicate metric name {:?} in area {:?}",
            m.name,
            self.area
        );
        self.metrics.push(m);
        Ok(())
    }

    /// Convenience: append a metric gated at the diff-wide default
    /// tolerance.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str, higher_is_better: bool) -> Result<()> {
        self.push(Metric {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
            higher_is_better,
            tol_pct: None,
        })
    }

    /// Convenience: append a metric with its own gate tolerance (percent).
    pub fn metric_tol(
        &mut self,
        name: &str,
        value: f64,
        unit: &str,
        higher_is_better: bool,
        tol_pct: f64,
    ) -> Result<()> {
        self.push(Metric {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
            higher_is_better,
            tol_pct: Some(tol_pct),
        })
    }

    /// Look up a metric by exact name.
    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.metrics.iter().find(|m| m.name == name)
    }

    /// Re-check every invariant [`BenchReport::push`] enforces (the
    /// fields are public, so `save` revalidates before writing).
    pub fn validate(&self) -> Result<()> {
        let mut check = BenchReport::new(&self.area);
        for m in &self.metrics {
            check.push(m.clone())?;
        }
        Ok(())
    }

    /// Serialise (schema, area, meta, metrics) preserving order.
    pub fn to_json(&self) -> Json {
        let mut meta = Json::obj();
        for (k, v) in &self.meta {
            meta = meta.set(k, v.as_str());
        }
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|m| {
                let mut j = Json::obj()
                    .set("name", m.name.as_str())
                    .set("value", m.value)
                    .set("unit", m.unit.as_str())
                    .set("higher_is_better", m.higher_is_better);
                if let Some(t) = m.tol_pct {
                    j = j.set("tol_pct", t);
                }
                j
            })
            .collect();
        Json::obj()
            .set("schema", SCHEMA)
            .set("area", self.area.as_str())
            .set("meta", meta)
            .set("metrics", Json::Arr(metrics))
    }

    /// Parse and validate a report. Wrong schema tags, missing fields,
    /// non-finite or non-numeric values (a NaN clamps to `null` in
    /// JSON — it is rejected here, not zeroed) and duplicate names are
    /// all errors.
    pub fn from_json(j: &Json) -> Result<BenchReport> {
        let schema = j.req("schema")?.as_str().ok_or_else(|| crate::err!("schema must be a string"))?;
        crate::ensure!(schema == SCHEMA, "unsupported bench-report schema {schema:?} (want {SCHEMA:?})");
        let area = j.req("area")?.as_str().ok_or_else(|| crate::err!("area must be a string"))?;
        crate::ensure!(!area.is_empty(), "empty area");
        let mut report = BenchReport::new(area);
        if let Some(meta) = j.get("meta") {
            let kv = meta.as_obj().ok_or_else(|| crate::err!("meta must be an object"))?;
            for (k, v) in kv {
                let vs = v
                    .as_str()
                    .ok_or_else(|| crate::err!("meta value for {k:?} must be a string"))?;
                report.meta(k, vs);
            }
        }
        let metrics = j
            .req("metrics")?
            .as_arr()
            .ok_or_else(|| crate::err!("metrics must be an array"))?;
        for (i, mj) in metrics.iter().enumerate() {
            let name = mj
                .req("name")?
                .as_str()
                .ok_or_else(|| crate::err!("metric #{i}: name must be a string"))?
                .to_string();
            let value = mj
                .req("value")?
                .as_f64()
                .ok_or_else(|| crate::err!("metric {name:?}: value must be a finite number"))?;
            let unit = mj
                .req("unit")?
                .as_str()
                .ok_or_else(|| crate::err!("metric {name:?}: unit must be a string"))?
                .to_string();
            let higher_is_better = mj
                .req("higher_is_better")?
                .as_bool()
                .ok_or_else(|| crate::err!("metric {name:?}: higher_is_better must be a bool"))?;
            let tol_pct = match mj.get("tol_pct") {
                None => None,
                Some(t) => Some(
                    t.as_f64()
                        .ok_or_else(|| crate::err!("metric {name:?}: tol_pct must be a finite number"))?,
                ),
            };
            report.push(Metric { name, value, unit, higher_is_better, tol_pct })?;
        }
        Ok(report)
    }

    /// Write the report to `path`, pretty-printed with a trailing
    /// newline, after revalidating invariants.
    pub fn save(&self, path: &str) -> Result<()> {
        use crate::util::error::Context;
        self.validate()?;
        let mut body = self.to_json().to_string_pretty();
        body.push('\n');
        std::fs::write(path, body).with_context(|| format!("writing bench report {path}"))?;
        Ok(())
    }

    /// Read and validate a report from `path`.
    pub fn load(path: &str) -> Result<BenchReport> {
        use crate::util::error::Context;
        let body =
            std::fs::read_to_string(path).with_context(|| format!("reading bench report {path}"))?;
        let j = parse(&body).with_context(|| format!("parsing bench report {path}"))?;
        BenchReport::from_json(&j).with_context(|| format!("validating bench report {path}"))
    }
}

// ---------------------------------------------------------------------------
// Diffing / regression gating
// ---------------------------------------------------------------------------

/// Outcome of comparing one metric between baseline and candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffStatus {
    /// Within the tolerance band (symmetric: ±tol around the baseline).
    Unchanged,
    /// Moved beyond tolerance in the *better* direction.
    Improved,
    /// Moved beyond tolerance in the *worse* direction — fails the gate.
    Regressed,
    /// Present in the baseline, absent from the candidate — hard error
    /// (a silently dropped metric must never pass the gate).
    Missing,
    /// Present only in the candidate — informational, not gated (lets
    /// the trajectory grow metrics without a baseline refresh).
    New,
    /// Unit / direction / area disagreement between the files — hard
    /// error: the comparison itself is meaningless.
    Mismatched,
}

impl DiffStatus {
    fn label(self) -> &'static str {
        match self {
            DiffStatus::Unchanged => "ok",
            DiffStatus::Improved => "improved",
            DiffStatus::Regressed => "REGRESSED",
            DiffStatus::Missing => "MISSING",
            DiffStatus::New => "new",
            DiffStatus::Mismatched => "MISMATCHED",
        }
    }
}

/// One row of a [`DiffReport`].
#[derive(Clone, Debug)]
pub struct DiffRow {
    /// Metric name (or `<area>` for a report-level mismatch).
    pub name: String,
    /// Baseline value, when the metric exists there.
    pub base: Option<f64>,
    /// Candidate value, when the metric exists there.
    pub cand: Option<f64>,
    /// Signed relative change in percent (positive = value went up);
    /// ±inf when the baseline is exactly 0 and the candidate is not.
    pub change_pct: f64,
    /// Tolerance applied to this row, in percent.
    pub tol_pct: f64,
    /// Verdict.
    pub status: DiffStatus,
}

/// Result of [`diff`]: per-metric rows plus gate accounting.
#[derive(Clone, Debug)]
pub struct DiffReport {
    /// One row per union-of-names metric, baseline order first.
    pub rows: Vec<DiffRow>,
    /// The diff-wide default tolerance that applied where no per-metric
    /// tolerance was set.
    pub default_tol_pct: f64,
}

impl DiffReport {
    /// Metrics that moved beyond tolerance in the worse direction.
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.status == DiffStatus::Regressed).count()
    }

    /// Structural failures: missing metrics, unit/direction/area
    /// mismatches.
    pub fn hard_errors(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| matches!(r.status, DiffStatus::Missing | DiffStatus::Mismatched))
            .count()
    }

    /// True when the candidate passes the gate.
    pub fn gate_ok(&self) -> bool {
        self.regressions() == 0 && self.hard_errors() == 0
    }

    /// Render the readable comparison table `bench_diff` prints.
    pub fn to_table(&self) -> Table {
        fn fmt(v: Option<f64>) -> String {
            match v {
                None => "-".into(),
                Some(x) if x == 0.0 => "0".into(),
                Some(x) if x.abs() >= 1e4 || x.abs() < 1e-3 => format!("{x:.3e}"),
                Some(x) => format!("{x:.4}"),
            }
        }
        let mut t = Table::new(&["metric", "baseline", "candidate", "change", "tol", "status"]);
        for r in &self.rows {
            let change = if r.base.is_none() || r.cand.is_none() {
                "-".into()
            } else if r.change_pct.is_infinite() {
                format!("{}inf%", if r.change_pct > 0.0 { "+" } else { "-" })
            } else {
                format!("{:+.1}%", r.change_pct)
            };
            t.row(&[
                r.name.clone(),
                fmt(r.base),
                fmt(r.cand),
                change,
                format!("±{:.1}%", r.tol_pct),
                r.status.label().into(),
            ]);
        }
        t
    }

    /// One-line verdict (`bench_diff`'s last stdout line).
    pub fn summary(&self) -> String {
        format!(
            "{} metrics compared: {} regressed, {} hard errors, {} improved ({})",
            self.rows.len(),
            self.regressions(),
            self.hard_errors(),
            self.rows.iter().filter(|r| r.status == DiffStatus::Improved).count(),
            if self.gate_ok() { "gate: OK" } else { "gate: FAIL" },
        )
    }
}

/// Compare `cand` against `base` under per-metric tolerances.
///
/// Semantics (pinned by tests/bench_report.rs):
/// * tolerance band is symmetric around the baseline value; only a
///   move beyond tolerance in the metric's *worse* direction
///   (`higher_is_better`-aware) regresses;
/// * the applied tolerance is the **baseline** metric's `tol_pct` when
///   set, else `default_tol_pct` — the committed baseline carries the
///   gate thresholds;
/// * baseline metric missing from the candidate → [`DiffStatus::Missing`]
///   (hard error); candidate-only metrics → [`DiffStatus::New`] (not
///   gated);
/// * unit or direction disagreement → [`DiffStatus::Mismatched`] (hard
///   error), as is an area mismatch between the two reports;
/// * a zero baseline with a non-zero candidate is an infinite change:
///   regression or improvement purely by direction.
pub fn diff(base: &BenchReport, cand: &BenchReport, default_tol_pct: f64) -> DiffReport {
    let mut rows = Vec::new();
    if base.area != cand.area {
        rows.push(DiffRow {
            name: format!("<area: {:?} vs {:?}>", base.area, cand.area),
            base: None,
            cand: None,
            change_pct: 0.0,
            tol_pct: default_tol_pct,
            status: DiffStatus::Mismatched,
        });
    }
    for bm in &base.metrics {
        let tol = bm.tol_pct.unwrap_or(default_tol_pct);
        let row = match cand.get(&bm.name) {
            None => DiffRow {
                name: bm.name.clone(),
                base: Some(bm.value),
                cand: None,
                change_pct: 0.0,
                tol_pct: tol,
                status: DiffStatus::Missing,
            },
            Some(cm) if cm.unit != bm.unit || cm.higher_is_better != bm.higher_is_better => DiffRow {
                name: bm.name.clone(),
                base: Some(bm.value),
                cand: Some(cm.value),
                change_pct: 0.0,
                tol_pct: tol,
                status: DiffStatus::Mismatched,
            },
            Some(cm) => {
                let change_pct = if bm.value == 0.0 {
                    if cm.value == 0.0 {
                        0.0
                    } else if cm.value > 0.0 {
                        f64::INFINITY
                    } else {
                        f64::NEG_INFINITY
                    }
                } else {
                    (cm.value - bm.value) / bm.value.abs() * 100.0
                };
                // positive `worse` = moved against the metric's good
                // direction
                let worse = if bm.higher_is_better { -change_pct } else { change_pct };
                let status = if worse > tol {
                    DiffStatus::Regressed
                } else if -worse > tol {
                    DiffStatus::Improved
                } else {
                    DiffStatus::Unchanged
                };
                DiffRow {
                    name: bm.name.clone(),
                    base: Some(bm.value),
                    cand: Some(cm.value),
                    change_pct,
                    tol_pct: tol,
                    status,
                }
            }
        };
        rows.push(row);
    }
    for cm in &cand.metrics {
        if base.get(&cm.name).is_none() {
            rows.push(DiffRow {
                name: cm.name.clone(),
                base: None,
                cand: Some(cm.value),
                change_pct: 0.0,
                tol_pct: cm.tol_pct.unwrap_or(default_tol_pct),
                status: DiffStatus::New,
            });
        }
    }
    DiffReport { rows, default_tol_pct }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(pairs: &[(&str, f64, bool)]) -> BenchReport {
        let mut r = BenchReport::new("t");
        for (n, v, hib) in pairs {
            r.metric(n, *v, "u", *hib).unwrap();
        }
        r
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut r = BenchReport::new("engine");
        r.meta("family", "image");
        r.meta("steps", 10);
        r.metric("throughput_rps", 123.456, "req/s", true).unwrap();
        r.metric_tol("p95_s", 0.25, "s", false, 60.0).unwrap();
        let back = BenchReport::from_json(&parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn nan_and_inf_rejected_at_insert() {
        let mut r = BenchReport::new("t");
        assert!(r.metric("bad", f64::NAN, "u", true).is_err());
        assert!(r.metric("bad", f64::INFINITY, "u", true).is_err());
        assert!(r.metric_tol("bad", 1.0, "u", true, f64::NAN).is_err());
        assert!(r.metric_tol("bad", 1.0, "u", true, -5.0).is_err());
        assert!(r.metrics.is_empty());
    }

    #[test]
    fn null_value_rejected_at_load_not_zeroed() {
        // a NaN clamps to null under util::json; from_json must reject
        let j = Json::obj().set("schema", SCHEMA).set("area", "t").set(
            "metrics",
            Json::Arr(vec![Json::obj()
                .set("name", "m")
                .set("value", Json::Null)
                .set("unit", "u")
                .set("higher_is_better", true)]),
        );
        let e = BenchReport::from_json(&j).unwrap_err();
        assert!(e.to_string().contains("finite"), "{e}");
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut r = BenchReport::new("t");
        r.metric("m", 1.0, "u", true).unwrap();
        assert!(r.metric("m", 2.0, "u", true).is_err());
    }

    #[test]
    fn diff_direction_and_symmetry() {
        // higher-is-better: a drop beyond tol regresses, a gain improves
        let base = report(&[("up", 100.0, true), ("down", 100.0, false)]);
        let worse = report(&[("up", 85.0, true), ("down", 115.0, false)]);
        let d = diff(&base, &worse, 10.0);
        assert!(d.rows.iter().all(|r| r.status == DiffStatus::Regressed), "{:?}", d.rows);
        let better = report(&[("up", 115.0, true), ("down", 85.0, false)]);
        let d = diff(&base, &better, 10.0);
        assert!(d.rows.iter().all(|r| r.status == DiffStatus::Improved));
        assert!(d.gate_ok());
        let within = report(&[("up", 91.0, true), ("down", 109.0, false)]);
        let d = diff(&base, &within, 10.0);
        assert!(d.rows.iter().all(|r| r.status == DiffStatus::Unchanged));
    }

    #[test]
    fn diff_missing_metric_is_hard_error() {
        let base = report(&[("kept", 1.0, true), ("dropped", 1.0, true)]);
        let cand = report(&[("kept", 1.0, true)]);
        let d = diff(&base, &cand, 10.0);
        assert_eq!(d.hard_errors(), 1);
        assert!(!d.gate_ok());
    }

    #[test]
    fn diff_new_metric_not_gated() {
        let base = report(&[("a", 1.0, true)]);
        let cand = report(&[("a", 1.0, true), ("b", 9.0, true)]);
        let d = diff(&base, &cand, 10.0);
        assert!(d.gate_ok());
        assert!(d.rows.iter().any(|r| r.status == DiffStatus::New && r.name == "b"));
    }

    #[test]
    fn diff_unit_or_direction_mismatch_is_hard_error() {
        let base = report(&[("m", 1.0, true)]);
        let cand = report(&[("m", 1.0, false)]);
        assert_eq!(diff(&base, &cand, 10.0).hard_errors(), 1);
        let mut cand2 = BenchReport::new("t");
        cand2.push(Metric {
            name: "m".into(),
            value: 1.0,
            unit: "other".into(),
            higher_is_better: true,
            tol_pct: None,
        })
        .unwrap();
        assert_eq!(diff(&base, &cand2, 10.0).hard_errors(), 1);
    }

    #[test]
    fn diff_per_metric_tolerance_overrides_default() {
        let mut base = BenchReport::new("t");
        base.metric_tol("loose", 100.0, "u", true, 50.0).unwrap();
        base.metric("tight", 100.0, "u", true).unwrap();
        let cand = report(&[("loose", 70.0, true), ("tight", 70.0, true)]);
        let d = diff(&base, &cand, 10.0);
        let by_name = |n: &str| d.rows.iter().find(|r| r.name == n).unwrap().status;
        assert_eq!(by_name("loose"), DiffStatus::Unchanged);
        assert_eq!(by_name("tight"), DiffStatus::Regressed);
    }

    #[test]
    fn diff_zero_baseline() {
        let base = report(&[("z", 0.0, false)]);
        assert!(diff(&base, &report(&[("z", 0.0, false)]), 10.0).gate_ok());
        let d = diff(&base, &report(&[("z", 0.5, false)]), 10.0);
        assert_eq!(d.rows[0].status, DiffStatus::Regressed);
        assert!(d.rows[0].change_pct.is_infinite());
    }

    #[test]
    fn diff_area_mismatch_is_hard_error() {
        let base = BenchReport::new("a");
        let cand = BenchReport::new("b");
        assert_eq!(diff(&base, &cand, 10.0).hard_errors(), 1);
    }

    #[test]
    fn table_and_summary_render() {
        let base = report(&[("m", 100.0, true)]);
        let cand = report(&[("m", 50.0, true)]);
        let d = diff(&base, &cand, 10.0);
        let t = d.to_table().to_string();
        assert!(t.contains("REGRESSED"), "{t}");
        assert!(d.summary().contains("gate: FAIL"));
    }
}
