//! Benchmark harness (substrate; no criterion offline).
//!
//! Every `[[bench]]` target in this repo uses `harness = false` and this
//! module: warmup, timed iterations, robust statistics, aligned table
//! printing, a typed argv parser ([`Args`]), and the machine-readable
//! [`report`] layer (`BENCH_<area>.json` trajectory files plus the
//! `bench_diff` regression gate — docs/benchmarks.md).
//!
//! Run-size tiers: `SMOOTHCACHE_BENCH_FAST=1` trims sample counts for
//! quick local runs; the `--smoke` flag (every bench target accepts it)
//! implies fast mode *and* shrinks the workload itself (steps, batch,
//! roster) to CI-seconds scale so the full bench matrix can run — and
//! emit its JSON trajectory — inside `scripts/verify.sh`.

pub mod report;

use crate::util::error::Result;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: xs[0],
            max_s: xs[n - 1],
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
        }
    }
}

/// Fast mode trims warmup/iteration counts: `SMOOTHCACHE_BENCH_FAST=1`
/// or the `--smoke` flag (which additionally shrinks the workload —
/// see [`smoke_mode`]).
pub fn fast_mode() -> bool {
    std::env::var("SMOOTHCACHE_BENCH_FAST").map(|v| v == "1").unwrap_or(false) || smoke_mode()
}

/// True when the bench binary was invoked with `--smoke`: the tiny
/// CI-scale configuration (2-ish steps, one family, minimal rosters)
/// that `scripts/verify.sh` and `tests/bench_smoke.rs` run. Implies
/// [`fast_mode`].
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Typed argv parser for `harness = false` bench binaries and the
/// `bench_diff` tool.
///
/// Grammar: `--name value`, `--name=value`, bare `--name` presence
/// flags, and positional operands. Unlike the pre-PR-6 `arg_usize`
/// free function — which silently returned the default on a malformed
/// value and ignored typos — every failure mode here is a typed
/// [`Error`](crate::util::error::Error):
///
/// * malformed value (`--threads abc`) — error naming flag and value;
/// * duplicate flag (`--threads 1 --threads=2`) — error;
/// * bare flag given a value (`--smoke=1`) or value flag left bare —
///   error;
/// * unknown/unconsumed arguments — error from [`Args::finish`]
///   (cargo's own `--bench` injection is whitelisted).
///
/// Accessors mark their tokens consumed; call [`Args::finish`] last.
pub struct Args {
    argv: Vec<String>,
    used: std::cell::RefCell<Vec<bool>>,
}

impl Args {
    /// Parse the process argv (minus the binary name).
    pub fn parse() -> Args {
        Args::from_vec(std::env::args().skip(1).collect())
    }

    /// Build from an explicit token list (tests).
    pub fn from_vec(argv: Vec<String>) -> Args {
        let used = std::cell::RefCell::new(vec![false; argv.len()]);
        Args { argv, used }
    }

    /// Locate `--name`, marking its tokens consumed. Only a
    /// value-taking accessor consumes the following token (so a bare
    /// presence flag next to a positional operand never swallows it).
    /// Returns the value (`Some` for `--name v` / `--name=v`, `None`
    /// for a bare occurrence); outer `None` when absent. Errors on
    /// duplicates.
    fn find(&self, name: &str, wants_value: bool) -> Result<Option<Option<String>>> {
        let flag = format!("--{name}");
        let prefix = format!("--{name}=");
        let mut found: Option<Option<String>> = None;
        let mut used = self.used.borrow_mut();
        let mut i = 0;
        while i < self.argv.len() {
            let a = &self.argv[i];
            let hit = if *a == flag {
                used[i] = true;
                // `--name value`: the next token is the value unless it
                // is itself a flag (leading `--`; a single `-` may open
                // a negative number)
                match self.argv.get(i + 1) {
                    Some(v) if wants_value && !v.starts_with("--") => {
                        used[i + 1] = true;
                        i += 1;
                        Some(Some(v.clone()))
                    }
                    _ => Some(None),
                }
            } else if let Some(rest) = a.strip_prefix(&prefix) {
                used[i] = true;
                Some(Some(rest.to_string()))
            } else {
                None
            };
            if let Some(v) = hit {
                crate::ensure!(found.is_none(), "duplicate flag --{name}");
                found = Some(v);
            }
            i += 1;
        }
        Ok(found)
    }

    /// `--name` as a bare presence flag. Errors if it was given a value.
    pub fn flag(&self, name: &str) -> Result<bool> {
        match self.find(name, false)? {
            None => Ok(false),
            Some(None) => Ok(true),
            Some(Some(v)) => Err(crate::err!("flag --{name} takes no value (got {v:?})")),
        }
    }

    /// `--name VALUE` as a string, if present. Errors if left bare.
    pub fn str_opt(&self, name: &str) -> Result<Option<String>> {
        match self.find(name, true)? {
            None => Ok(None),
            Some(Some(v)) => Ok(Some(v)),
            Some(None) => Err(crate::err!("missing value for --{name}")),
        }
    }

    /// `--name N` as a usize, with a default when absent. A present but
    /// unparsable value is an error, not the default.
    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.str_opt(name)? {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| crate::err!("invalid value for --{name}: {v:?} (expected an unsigned integer)")),
        }
    }

    /// `--name X` as an f64, with a default when absent. Non-finite
    /// values (`nan`, `inf`) are rejected — every consumer here is a
    /// threshold or knob where they would poison comparisons.
    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.str_opt(name)? {
            None => Ok(default),
            Some(v) => {
                let x: f64 = v
                    .parse()
                    .map_err(|_| crate::err!("invalid value for --{name}: {v:?} (expected a number)"))?;
                crate::ensure!(x.is_finite(), "invalid value for --{name}: {v:?} (must be finite)");
                Ok(x)
            }
        }
    }

    /// Remaining non-flag tokens, in order, marked consumed. Call after
    /// every flag accessor (a value-bearing flag's operand would
    /// otherwise be misread as positional).
    pub fn positional(&self) -> Vec<String> {
        let mut used = self.used.borrow_mut();
        let mut out = Vec::new();
        for (i, a) in self.argv.iter().enumerate() {
            if !used[i] && !a.starts_with("--") {
                used[i] = true;
                out.push(a.clone());
            }
        }
        out
    }

    /// Error on any argument no accessor consumed. Cargo passes
    /// `--bench` to `harness = false` targets under `cargo bench`, so a
    /// bare `--bench` is tolerated; everything else unknown fails.
    pub fn finish(&self) -> Result<()> {
        let used = self.used.borrow();
        for (i, a) in self.argv.iter().enumerate() {
            if used[i] || a == "--bench" {
                continue;
            }
            if a.starts_with("--") {
                crate::bail!("unknown flag {a}");
            }
            crate::bail!("unexpected argument {a:?}");
        }
        Ok(())
    }
}

/// One-flag convenience over [`Args`] with the historical `arg_usize`
/// name: parse `--name N` from this binary's argv. Malformed or
/// duplicated values are typed errors (they used to silently fall back
/// to the default); unknown flags are diagnosed only by the full
/// [`Args`] workflow (`parse` → accessors → `finish`), which the bench
/// targets use.
pub fn arg_usize(name: &str, default: usize) -> Result<usize> {
    Args::parse().usize(name, default)
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    let (warmup, iters) = if fast_mode() {
        (warmup.min(1), iters.clamp(1, 3))
    } else {
        (warmup, iters)
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Simple stopwatch for one-shot timings inside bench tables.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Aligned text table, used by every bench to print paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }

    /// CSV for downstream plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Render a series as a crude ASCII plot (for figure benches).
pub fn ascii_plot(title: &str, series: &[(String, Vec<f64>)], height: usize) -> String {
    let all: Vec<f64> = series.iter().flat_map(|(_, ys)| ys.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}\n(empty)\n");
    }
    let (lo, hi) = all.iter().fold((f64::MAX, f64::MIN), |(l, h), &v| (l.min(v), h.max(v)));
    let span = (hi - lo).max(1e-12);
    let width = series.iter().map(|(_, ys)| ys.len()).max().unwrap();
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (x, &y) in ys.iter().enumerate() {
            let r = (((y - lo) / span) * (height - 1) as f64).round() as usize;
            grid[height - 1 - r][x] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}  [min={lo:.4}, max={hi:.4}]\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s);
        assert!(s.p95_s <= s.p99_s && s.p99_s <= s.max_s);
        assert!((s.mean_s - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        std::env::remove_var("SMOOTHCACHE_BENCH_FAST");
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn arg_usize_falls_back_to_default() {
        // the test harness argv carries no such flag
        assert_eq!(arg_usize("definitely-not-a-flag", 7).unwrap(), 7);
    }

    fn args(toks: &[&str]) -> Args {
        Args::from_vec(toks.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn args_happy_path() {
        let a = args(&["--threads", "4", "--json=out.json", "--smoke", "base", "cand"]);
        assert_eq!(a.usize("threads", 0).unwrap(), 4);
        assert_eq!(a.str_opt("json").unwrap().as_deref(), Some("out.json"));
        assert!(a.flag("smoke").unwrap());
        assert!(!a.flag("quiet").unwrap());
        assert_eq!(a.positional(), vec!["base", "cand"]);
        a.finish().unwrap();
    }

    #[test]
    fn args_malformed_value_is_error_not_default() {
        let a = args(&["--threads", "abc"]);
        let e = a.usize("threads", 3).unwrap_err();
        assert!(e.to_string().contains("--threads"), "{e}");
    }

    #[test]
    fn args_duplicate_flag_is_error() {
        let a = args(&["--threads", "1", "--threads=2"]);
        let e = a.usize("threads", 0).unwrap_err();
        assert!(e.to_string().contains("duplicate"), "{e}");
    }

    #[test]
    fn args_unknown_flag_fails_finish_but_cargo_bench_is_tolerated() {
        let a = args(&["--bench", "--typo-flag"]);
        let e = a.finish().unwrap_err();
        assert!(e.to_string().contains("--typo-flag"), "{e}");
        args(&["--bench"]).finish().unwrap();
    }

    #[test]
    fn args_value_flag_left_bare_is_error() {
        let a = args(&["--json"]);
        assert!(a.str_opt("json").unwrap_err().to_string().contains("missing value"));
    }

    #[test]
    fn args_bare_flag_with_value_is_error() {
        let a = args(&["--smoke=1"]);
        assert!(a.flag("smoke").unwrap_err().to_string().contains("takes no value"));
    }

    #[test]
    fn args_bare_flag_does_not_swallow_positionals() {
        let a = args(&["--smoke", "base.json"]);
        assert!(a.flag("smoke").unwrap());
        assert_eq!(a.positional(), vec!["base.json"]);
        a.finish().unwrap();
    }

    #[test]
    fn args_f64_rejects_non_finite() {
        assert!(args(&["--tol", "nan"]).f64("tol", 1.0).unwrap_err().to_string().contains("finite"));
        assert!((args(&["--tol", "2.5"]).f64("tol", 1.0).unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn table_alignment_and_csv() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "2.5".into()]);
        let s = t.to_string();
        assert!(s.contains("a-much-longer-name"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("name,value"));
    }

    #[test]
    fn ascii_plot_contains_series() {
        let p = ascii_plot("t", &[("a".into(), vec![0.0, 1.0, 0.5])], 5);
        assert!(p.contains('*'));
        assert!(p.contains("a"));
    }
}
