//! Minimal JSON parser/serializer (substrate).
//!
//! The offline crate set has no serde/serde_json; this module implements
//! the subset the repo needs — full RFC 8259 value model, recursive
//! descent parser with string escapes and scientific numbers, compact and
//! pretty serialization. Object key order is preserved (important for
//! stable manifests and schedule files).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), val.into()));
        }
        self
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing ergonomics.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { msg: format!("missing key {key:?}"), pos: 0 })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Lossless unsigned-integer view: `Some` iff the value is a number
    /// that is an exact non-negative integer within f64's 53-bit
    /// mantissa (`0 ..= 2^53 - 1`). Anything else — negative,
    /// fractional, non-numeric, or too large to survive the JSON
    /// number model without rounding — is `None`, so callers can
    /// reject it instead of silently truncating (`seed` parsing,
    /// docs/protocol.md).
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_SAFE: f64 = 9_007_199_254_740_991.0; // 2^53 - 1
        let n = self.as_f64()?;
        if n.is_finite() && (0.0..=MAX_SAFE).contains(&n) && n.fract() == 0.0 {
            Some(n as u64)
        } else {
            None
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: array of f64. `None` unless the value is an array
    /// and *every* element is numeric — a mixed-type array like
    /// `[1, "x", 3]` is rejected whole rather than silently dropping
    /// the non-numeric elements, so wire callers surface a typed error
    /// instead of acting on a shortened vector.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of f32 under the same all-or-`None` rule as
    /// [`Json::as_f64_vec`].
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    /// Array of usize under the same all-or-`None` rule as
    /// [`Json::as_f64_vec`].
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- serialization -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; clamp like common serializers do.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.b[self.i..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Lazy field scanner (protocol v2 hot path, ADR-008)
// ---------------------------------------------------------------------------

/// A top-level field value found by [`scan_field`] without building a
/// tree. String values borrow from the input document.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scanned<'a> {
    /// String value containing no escapes (zero-copy slice).
    Str(&'a str),
    /// Numeric value.
    Num(f64),
    /// Boolean value.
    Bool(bool),
    /// `null`.
    Null,
    /// Present, but an object/array or an escaped string — callers
    /// needing it should fall back to the full [`parse`].
    Complex,
}

/// Extract one top-level field of a JSON object without allocating or
/// building the full value tree.
///
/// Walks the object's top level, skipping non-matching values
/// (strings escape-aware, nested containers by depth counting), and
/// returns the matching value as a [`Scanned`]. Keys inside nested
/// objects and text inside string values are never matched. Returns
/// `None` if the document is not an object, the key is absent, or the
/// input is malformed before the key is found — callers on the wire
/// path fall back to [`parse`] for the authoritative error.
///
/// This is the envelope fast path for protocol v2 (`cmd`/`id`/
/// `stream` extraction): ~one linear scan, zero allocations, versus a
/// full tree build that copies every string and number in the request.
pub fn scan_field<'a>(doc: &'a str, key: &str) -> Option<Scanned<'a>> {
    let b = doc.as_bytes();
    let mut i = 0usize;
    let skip_ws = |b: &[u8], mut i: usize| {
        while i < b.len() && matches!(b[i], b' ' | b'\t' | b'\n' | b'\r') {
            i += 1;
        }
        i
    };
    // raw string scan: returns (content_start, content_end, had_escape,
    // index after closing quote); input index must sit on the `"`.
    let scan_string = |b: &[u8], mut i: usize| -> Option<(usize, usize, bool, usize)> {
        if b.get(i) != Some(&b'"') {
            return None;
        }
        i += 1;
        let start = i;
        let mut escaped = false;
        while i < b.len() {
            match b[i] {
                b'"' => return Some((start, i, escaped, i + 1)),
                b'\\' => {
                    escaped = true;
                    i += 2; // skip the escaped byte (\uXXXX still lands inside hex, fine)
                }
                _ => i += 1,
            }
        }
        None
    };
    // skip one value of any shape; returns index just past it.
    let skip_value = |b: &[u8], mut i: usize| -> Option<usize> {
        match *b.get(i)? {
            b'"' => scan_string(b, i).map(|(_, _, _, after)| after),
            b'{' | b'[' => {
                let mut depth = 0usize;
                while i < b.len() {
                    match b[i] {
                        b'{' | b'[' => depth += 1,
                        b'}' | b']' => {
                            depth -= 1;
                            if depth == 0 {
                                return Some(i + 1);
                            }
                        }
                        b'"' => {
                            let (_, _, _, after) = scan_string(b, i)?;
                            i = after;
                            continue;
                        }
                        _ => {}
                    }
                    i += 1;
                }
                None
            }
            _ => {
                // number / true / false / null: run to a delimiter
                let start = i;
                while i < b.len() && !matches!(b[i], b',' | b'}' | b']' | b' ' | b'\t' | b'\n' | b'\r')
                {
                    i += 1;
                }
                if i == start {
                    None
                } else {
                    Some(i)
                }
            }
        }
    };

    i = skip_ws(b, i);
    if b.get(i) != Some(&b'{') {
        return None;
    }
    i = skip_ws(b, i + 1);
    if b.get(i) == Some(&b'}') {
        return None;
    }
    loop {
        // key
        let (ks, ke, kesc, after) = scan_string(b, i)?;
        let matches = !kesc && &doc[ks..ke] == key;
        i = skip_ws(b, after);
        if b.get(i) != Some(&b':') {
            return None;
        }
        i = skip_ws(b, i + 1);
        if matches {
            return match *b.get(i)? {
                b'"' => {
                    let (vs, ve, vesc, _) = scan_string(b, i)?;
                    if vesc {
                        Some(Scanned::Complex)
                    } else {
                        Some(Scanned::Str(&doc[vs..ve]))
                    }
                }
                b'{' | b'[' => Some(Scanned::Complex),
                b't' => b[i..].starts_with(b"true").then_some(Scanned::Bool(true)),
                b'f' => b[i..].starts_with(b"false").then_some(Scanned::Bool(false)),
                b'n' => b[i..].starts_with(b"null").then_some(Scanned::Null),
                _ => {
                    let end = skip_value(b, i)?;
                    doc[i..end].parse::<f64>().ok().map(Scanned::Num)
                }
            };
        }
        i = skip_value(b, i)?;
        i = skip_ws(b, i);
        match b.get(i)? {
            b',' => i = skip_ws(b, i + 1),
            b'}' => return None,
            _ => return None,
        }
    }
}

/// [`scan_field`] narrowed to unescaped string values.
pub fn scan_str<'a>(doc: &'a str, key: &str) -> Option<&'a str> {
    match scan_field(doc, key)? {
        Scanned::Str(s) => Some(s),
        _ => None,
    }
}

/// [`scan_field`] narrowed to lossless unsigned integers (same rule as
/// [`Json::as_u64`]).
pub fn scan_u64(doc: &str, key: &str) -> Option<u64> {
    match scan_field(doc, key)? {
        Scanned::Num(n) => Json::Num(n).as_u64(),
        _ => None,
    }
}

/// [`scan_field`] narrowed to booleans.
pub fn scan_bool(doc: &str, key: &str) -> Option<bool> {
    match scan_field(doc, key)? {
        Scanned::Bool(v) => Some(v),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.25", "1e-3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" back\\ nl\n tab\t unicode\u{1F600}end";
        let v = Json::Str(s.to_string());
        assert_eq!(parse(&v.to_string()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(parse(r#""A""#).unwrap().as_str().unwrap(), "A");
        assert_eq!(parse(r#""😀""#).unwrap().as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"unterminated"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj()
            .set("nums", vec![1.0f64, 2.0, 3.0])
            .set("nested", Json::obj().set("x", true));
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn large_float_roundtrip() {
        let v = Json::Num(123456.789012);
        let back = parse(&v.to_string()).unwrap();
        assert!((back.as_f64().unwrap() - 123456.789012).abs() < 1e-9);
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn as_u64_is_lossless_or_none() {
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        // the largest exactly-representable integer round-trips…
        assert_eq!(parse("9007199254740991").unwrap().as_u64(), Some((1 << 53) - 1));
        // …but anything that f64 would have rounded is rejected
        assert_eq!(parse("9007199254740993").unwrap().as_u64(), None);
        assert_eq!(parse("18446744073709551615").unwrap().as_u64(), None);
        assert_eq!(parse("1e300").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None);
        assert_eq!(parse("null").unwrap().as_u64(), None);
    }

    #[test]
    fn helper_vectors() {
        let v = parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn helper_vectors_reject_mixed_types() {
        // regression: filter_map used to silently drop the "x",
        // returning [1, 3] — mixed arrays must be rejected whole
        let v = parse(r#"[1, "x", 3]"#).unwrap();
        assert_eq!(v.as_f64_vec(), None);
        assert_eq!(v.as_f32_vec(), None);
        assert_eq!(v.as_usize_vec(), None);
        for bad in [r#"[null]"#, r#"[1, true]"#, r#"[[1], 2]"#, r#"[1, {}]"#] {
            let v = parse(bad).unwrap();
            assert_eq!(v.as_f64_vec(), None, "{bad}");
            assert_eq!(v.as_usize_vec(), None, "{bad}");
        }
        // non-arrays and empty arrays keep their existing behavior
        assert_eq!(parse("3").unwrap().as_f64_vec(), None);
        assert_eq!(parse("[]").unwrap().as_f64_vec(), Some(vec![]));
    }

    #[test]
    fn scan_field_basics() {
        let doc = r#"{"cmd": "generate", "id": 42, "stream": true, "x": null}"#;
        assert_eq!(scan_field(doc, "cmd"), Some(Scanned::Str("generate")));
        assert_eq!(scan_field(doc, "id"), Some(Scanned::Num(42.0)));
        assert_eq!(scan_field(doc, "stream"), Some(Scanned::Bool(true)));
        assert_eq!(scan_field(doc, "x"), Some(Scanned::Null));
        assert_eq!(scan_field(doc, "missing"), None);
        assert_eq!(scan_str(doc, "cmd"), Some("generate"));
        assert_eq!(scan_u64(doc, "id"), Some(42));
        assert_eq!(scan_bool(doc, "stream"), Some(true));
    }

    #[test]
    fn scan_field_top_level_only() {
        // a key nested inside another value must not match
        let doc = r#"{"a": {"cmd": "inner"}, "b": [{"cmd": "deep"}], "cmd": "outer"}"#;
        assert_eq!(scan_field(doc, "cmd"), Some(Scanned::Str("outer")));
        // text inside a string value must not match either
        let doc = r#"{"a": "\"cmd\": \"fake\"", "cmd": "real"}"#;
        assert_eq!(scan_field(doc, "cmd"), Some(Scanned::Str("real")));
    }

    #[test]
    fn scan_field_complex_values() {
        let doc = r#"{"obj": {"k": 1}, "arr": [1,2], "esc": "a\nb"}"#;
        assert_eq!(scan_field(doc, "obj"), Some(Scanned::Complex));
        assert_eq!(scan_field(doc, "arr"), Some(Scanned::Complex));
        // escaped strings defer to the full parser rather than
        // allocating an unescape buffer
        assert_eq!(scan_field(doc, "esc"), Some(Scanned::Complex));
        assert_eq!(scan_str(doc, "esc"), None);
    }

    #[test]
    fn scan_field_rejects_garbage() {
        for bad in ["", "42", "[1,2]", "{", r#"{"a""#, r#"{"a": }"#, "not json"] {
            assert_eq!(scan_field(bad, "a"), None, "{bad:?}");
        }
        // truncated after the key we want → malformed value → None
        assert_eq!(scan_field(r#"{"cmd": "unterminated"#, "cmd"), None);
    }

    #[test]
    fn scan_field_matches_full_parse() {
        // parity corpus: the scanner must agree with the tree parser
        let docs = [
            r#"{"cmd":"ping","id":7,"stream":false}"#,
            r#"{ "id" : 9007199254740991 , "cmd" : "metrics" }"#,
            r#"{"deadline_ms": 1500.5, "policy": "smooth:0.1", "n": -3}"#,
            r#"{"a":[{"id":1}],"id":2,"b":"id","c":{"x":[1,2,{"y":"z"}]}}"#,
        ];
        for doc in docs {
            let tree = parse(doc).unwrap();
            for key in ["cmd", "id", "stream", "deadline_ms", "policy", "n", "b"] {
                let scanned = scan_field(doc, key);
                match tree.get(key) {
                    None => assert_eq!(scanned, None, "{doc} / {key}"),
                    Some(Json::Str(s)) => {
                        assert_eq!(scanned, Some(Scanned::Str(s.as_str())), "{doc} / {key}")
                    }
                    Some(Json::Num(n)) => {
                        assert_eq!(scanned, Some(Scanned::Num(*n)), "{doc} / {key}")
                    }
                    Some(Json::Bool(v)) => {
                        assert_eq!(scanned, Some(Scanned::Bool(*v)), "{doc} / {key}")
                    }
                    Some(Json::Null) => assert_eq!(scanned, Some(Scanned::Null)),
                    Some(_) => assert_eq!(scanned, Some(Scanned::Complex), "{doc} / {key}"),
                }
            }
        }
    }
}
