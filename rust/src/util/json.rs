//! Minimal JSON parser/serializer (substrate).
//!
//! The offline crate set has no serde/serde_json; this module implements
//! the subset the repo needs — full RFC 8259 value model, recursive
//! descent parser with string escapes and scientific numbers, compact and
//! pretty serialization. Object key order is preserved (important for
//! stable manifests and schedule files).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), val.into()));
        }
        self
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing ergonomics.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { msg: format!("missing key {key:?}"), pos: 0 })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Lossless unsigned-integer view: `Some` iff the value is a number
    /// that is an exact non-negative integer within f64's 53-bit
    /// mantissa (`0 ..= 2^53 - 1`). Anything else — negative,
    /// fractional, non-numeric, or too large to survive the JSON
    /// number model without rounding — is `None`, so callers can
    /// reject it instead of silently truncating (`seed` parsing,
    /// docs/protocol.md).
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_SAFE: f64 = 9_007_199_254_740_991.0; // 2^53 - 1
        let n = self.as_f64()?;
        if n.is_finite() && (0.0..=MAX_SAFE).contains(&n) && n.fract() == 0.0 {
            Some(n as u64)
        } else {
            None
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: array of f64.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as f32)).collect())
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    // ---- serialization -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; clamp like common serializers do.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.b[self.i..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.25", "1e-3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" back\\ nl\n tab\t unicode\u{1F600}end";
        let v = Json::Str(s.to_string());
        assert_eq!(parse(&v.to_string()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(parse(r#""A""#).unwrap().as_str().unwrap(), "A");
        assert_eq!(parse(r#""😀""#).unwrap().as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"unterminated"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj()
            .set("nums", vec![1.0f64, 2.0, 3.0])
            .set("nested", Json::obj().set("x", true));
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn large_float_roundtrip() {
        let v = Json::Num(123456.789012);
        let back = parse(&v.to_string()).unwrap();
        assert!((back.as_f64().unwrap() - 123456.789012).abs() < 1e-9);
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn as_u64_is_lossless_or_none() {
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        // the largest exactly-representable integer round-trips…
        assert_eq!(parse("9007199254740991").unwrap().as_u64(), Some((1 << 53) - 1));
        // …but anything that f64 would have rounded is rejected
        assert_eq!(parse("9007199254740993").unwrap().as_u64(), None);
        assert_eq!(parse("18446744073709551615").unwrap().as_u64(), None);
        assert_eq!(parse("1e300").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None);
        assert_eq!(parse("null").unwrap().as_u64(), None);
    }

    #[test]
    fn helper_vectors() {
        let v = parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }
}
