//! Mini property-based testing framework (substrate; no proptest offline).
//!
//! `forall` runs a property over `cases` generated inputs. On failure it
//! greedily shrinks the failing case via the `Shrink` trait before
//! reporting, and always prints the replay seed. Coordinator invariants
//! (routing, batching, schedule state machines) are tested with this.

use super::rng::Rng;

/// Types that can propose strictly-smaller variants of themselves.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<f64> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // shrink one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                for s in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone, C: Shrink + Clone> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<(A, B, C)> {
        let mut out: Vec<(A, B, C)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

pub struct PropError {
    pub seed: u64,
    pub case_index: usize,
    pub message: String,
}

impl std::fmt::Debug for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed (replay: seed={}, case={}): {}",
            self.seed, self.case_index, self.message
        )
    }
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the shrunk
/// counterexample on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink greedily
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in best.shrink() {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (replay seed={seed}, case={i})\n  shrunk input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::super::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }

    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.range_f64(lo, hi)
    }

    pub fn vec_of<T>(rng: &mut Rng, len_lo: usize, len_hi: usize, f: impl Fn(&mut Rng) -> T) -> Vec<T> {
        let n = rng.range(len_lo, len_hi);
        (0..n).map(|_| f(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            1,
            200,
            |r| gen::vec_of(r, 0, 20, |r| r.below(100)),
            |v: &Vec<usize>| {
                let mut s = v.clone();
                s.sort_unstable();
                if s.len() == v.len() {
                    Ok(())
                } else {
                    Err("sort changed length".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_replay() {
        forall(2, 100, |r| r.below(1000), |&x: &usize| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // capture the panic message and assert the shrunk value is minimal-ish
        let result = std::panic::catch_unwind(|| {
            forall(3, 100, |r| r.below(1000) + 500, |&x: &usize| {
                if x < 500 {
                    Ok(())
                } else {
                    Err("ge 500".into())
                }
            });
        });
        let msg = match result {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("should have failed"),
        };
        assert!(msg.contains("shrunk input: 500"), "msg: {msg}");
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v = vec![5usize, 6, 7];
        assert!(v.shrink().iter().all(|s| s.len() < v.len() || s.iter().sum::<usize>() < 18));
    }
}
