//! Deterministic pseudo-random generation (substrate).
//!
//! The offline crate set has no `rand`; this module provides everything
//! the runtime needs: SplitMix64 seeding, xoshiro256++ core, uniform /
//! normal / exponential / Poisson sampling, shuffles and choices. All
//! generators are explicitly seeded — every experiment in this repo is
//! reproducible from its config seed.

/// SplitMix64: used to expand a single u64 seed into xoshiro state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], spare: None }
    }

    /// Derive an independent stream (e.g. per-request, per-worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire-ish rejection-free for our sizes.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * v;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Exponential with rate `lambda` (inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u == 0.0 {
            u = f64::EPSILON;
        }
        -u.ln() / lambda
    }

    /// Poisson sample: Knuth for small lambda, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        let v = lambda + lambda.sqrt() * self.normal();
        if v < 0.0 {
            0
        } else {
            v.round() as u64
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniform choice from a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(13);
        for &lam in &[0.5, 4.0, 50.0] {
            let n = 20_000;
            let m: f64 = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam).abs() < lam.max(1.0) * 0.1, "lam={lam} m={m}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "m={m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(23);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(3);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
