//! Benchmark harness (substrate; no criterion offline).
//!
//! Every `[[bench]]` target in this repo uses `harness = false` and this
//! module: warmup, timed iterations, robust statistics, and aligned
//! table printing so each bench binary can emit the same rows/series as
//! the corresponding paper table or figure.
//!
//! `SMOOTHCACHE_BENCH_FAST=1` trims sample counts for smoke runs.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: xs[0],
            max_s: xs[n - 1],
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
        }
    }
}

pub fn fast_mode() -> bool {
    std::env::var("SMOOTHCACHE_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Parse `--name N` / `--name=N` from this bench binary's argv. Bench
/// targets run with `harness = false`, but cargo may still inject flags
/// of its own (e.g. `--bench`), so anything unrecognised is ignored
/// rather than rejected. Used for the `--threads` / `--workers` knobs.
pub fn arg_usize(name: &str, default: usize) -> usize {
    let flag = format!("--{name}");
    let prefix = format!("--{name}=");
    let args: Vec<String> = std::env::args().collect();
    for (i, a) in args.iter().enumerate() {
        if *a == flag {
            if let Some(v) = args.get(i + 1).and_then(|s| s.parse().ok()) {
                return v;
            }
        } else if let Some(rest) = a.strip_prefix(&prefix) {
            if let Ok(v) = rest.parse() {
                return v;
            }
        }
    }
    default
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    let (warmup, iters) = if fast_mode() {
        (warmup.min(1), iters.clamp(1, 3))
    } else {
        (warmup, iters)
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Simple stopwatch for one-shot timings inside bench tables.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Aligned text table, used by every bench to print paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }

    /// CSV for downstream plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Render a series as a crude ASCII plot (for figure benches).
pub fn ascii_plot(title: &str, series: &[(String, Vec<f64>)], height: usize) -> String {
    let all: Vec<f64> = series.iter().flat_map(|(_, ys)| ys.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}\n(empty)\n");
    }
    let (lo, hi) = all.iter().fold((f64::MAX, f64::MIN), |(l, h), &v| (l.min(v), h.max(v)));
    let span = (hi - lo).max(1e-12);
    let width = series.iter().map(|(_, ys)| ys.len()).max().unwrap();
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (x, &y) in ys.iter().enumerate() {
            let r = (((y - lo) / span) * (height - 1) as f64).round() as usize;
            grid[height - 1 - r][x] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}  [min={lo:.4}, max={hi:.4}]\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s);
        assert!(s.p95_s <= s.p99_s && s.p99_s <= s.max_s);
        assert!((s.mean_s - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        std::env::remove_var("SMOOTHCACHE_BENCH_FAST");
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn arg_usize_falls_back_to_default() {
        // the test harness argv carries no such flag
        assert_eq!(arg_usize("definitely-not-a-flag", 7), 7);
    }

    #[test]
    fn table_alignment_and_csv() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "2.5".into()]);
        let s = t.to_string();
        assert!(s.contains("a-much-longer-name"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("name,value"));
    }

    #[test]
    fn ascii_plot_contains_series() {
        let p = ascii_plot("t", &[("a".into(), vec![0.0, 1.0, 0.5])], 5);
        assert!(p.contains('*'));
        assert!(p.contains("a"));
    }
}
