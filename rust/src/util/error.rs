//! Minimal error handling for the zero-dependency default build
//! (docs/adr/001-zero-dependency-default-build.md).
//!
//! Stands in for `anyhow` with the subset this crate uses: a single
//! string-chained [`Error`] type, a [`Result`] alias with a defaulted
//! error parameter, a [`Context`] extension trait (`context` /
//! `with_context` on both `Result` and `Option`), and the
//! `err!` / `bail!` / `ensure!`
//! macros. Display renders the context chain outermost-first,
//! `"loading manifest: reading \"…\": No such file"` style, so existing
//! `{e}` / `{e:#}` call sites keep printing the full story.

use std::fmt;

/// A chain of human-readable messages; `chain[0]` is the outermost
/// context, the last entry is the root cause.
#[derive(Clone, Debug)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable (mirrors
    /// `anyhow::Error::msg`).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias; the defaulted parameter lets signatures
/// written for `anyhow::Result<T>` port unchanged.
pub type Result<T, E = Error> = std::result::Result<T, E>;

// ---- conversions (for `?` on common error sources) -------------------------

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { chain: vec![s] }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { chain: vec![s.to_string()] }
    }
}

// ---- context extension ------------------------------------------------------

/// `anyhow::Context`-shaped extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

// ---- macros -----------------------------------------------------------------

/// Build an [`Error`] from a format string (replaces `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::err!("root {}", 42))
    }

    #[test]
    fn display_chains_outermost_first() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32> = Ok(7);
        let v = ok.with_context(|| -> String { panic!("not evaluated on Ok") });
        assert_eq!(v.unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/here")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn check(v: i32) -> Result<i32> {
            crate::ensure!(v > 0, "need positive, got {v}");
            Ok(v)
        }
        assert!(check(-1).is_err());
        assert_eq!(check(3).unwrap(), 3);
    }
}
