//! Substrate utilities built from scratch for the offline environment
//! (DESIGN.md section 3, offline-crate substitutions).

pub mod bench;
pub mod cli;
pub mod error;
pub mod fft;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod threadpool;
