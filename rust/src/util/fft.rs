//! Radix-2 FFT and spectrogram utilities (substrate).
//!
//! Powers the audio-domain quality metrics: the paper evaluates Stable
//! Audio Open with FD_OpenL3 / KL_PaSST, both of which operate on
//! time-frequency representations. Our proxies (quality::audio) compute
//! log-magnitude spectrogram features through this module, so the
//! "audio metric looks at spectra" semantics survive the substitution
//! (DESIGN.md §3).

use std::f64::consts::PI;

/// In-place iterative radix-2 Cooley-Tukey FFT over interleaved
/// (re, im) pairs. `n` must be a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    // butterflies
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr0, vi0) = (re[i + k + len / 2], im[i + k + len / 2]);
                let vr = vr0 * cr - vi0 * ci;
                let vi = vr0 * ci + vi0 * cr;
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        let inv = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }
}

/// Forward FFT of a real signal; returns (re, im) of length n (padded to
/// the next power of two).
pub fn rfft(signal: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = signal.len().next_power_of_two();
    let mut re = signal.to_vec();
    re.resize(n, 0.0);
    let mut im = vec![0.0; n];
    fft_inplace(&mut re, &mut im, false);
    (re, im)
}

/// Magnitude spectrum (first n/2+1 bins) of a real signal.
pub fn magnitude_spectrum(signal: &[f64]) -> Vec<f64> {
    let (re, im) = rfft(signal);
    let n = re.len();
    (0..=n / 2).map(|i| (re[i] * re[i] + im[i] * im[i]).sqrt()).collect()
}

/// Hann window.
pub fn hann(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| 0.5 * (1.0 - (2.0 * PI * i as f64 / n as f64).cos()))
        .collect()
}

/// Log-magnitude STFT spectrogram: frames × (n_fft/2 + 1).
pub fn log_spectrogram(signal: &[f64], n_fft: usize, hop: usize) -> Vec<Vec<f64>> {
    assert!(n_fft.is_power_of_two() && hop > 0);
    let w = hann(n_fft);
    let mut frames = Vec::new();
    let mut start = 0;
    while start + n_fft <= signal.len().max(n_fft) {
        let mut frame = vec![0.0; n_fft];
        for i in 0..n_fft {
            let v = signal.get(start + i).copied().unwrap_or(0.0);
            frame[i] = v * w[i];
        }
        let mag = magnitude_spectrum(&frame);
        frames.push(mag.into_iter().map(|m| (m + 1e-8).ln()).collect());
        if start + hop + n_fft > signal.len() && start + n_fft >= signal.len() {
            break;
        }
        start += hop;
    }
    if frames.is_empty() {
        frames.push(vec![0.0; n_fft / 2 + 1]);
    }
    frames
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_roundtrip() {
        let mut re: Vec<f64> = (0..16).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut im = vec![0.0; 16];
        let orig = re.clone();
        fft_inplace(&mut re, &mut im, false);
        fft_inplace(&mut re, &mut im, true);
        for (a, b) in re.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-12);
        }
        for v in im {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_of_pure_tone_peaks_at_bin() {
        // cos(2π·4·t/N) → energy concentrated at bin 4
        let n = 64;
        let sig: Vec<f64> =
            (0..n).map(|i| (2.0 * PI * 4.0 * i as f64 / n as f64).cos()).collect();
        let mag = magnitude_spectrum(&sig);
        let peak = mag
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(peak, 4);
        assert!((mag[4] - n as f64 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn parseval_energy_conserved() {
        let n = 128;
        let mut rng = crate::util::rng::Rng::new(3);
        let sig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (re, im) = rfft(&sig);
        let time_e: f64 = sig.iter().map(|x| x * x).sum();
        let freq_e: f64 =
            re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f64>() / n as f64;
        assert!((time_e - freq_e).abs() < 1e-9 * time_e.max(1.0));
    }

    #[test]
    fn dc_signal_has_only_dc_bin() {
        let mag = magnitude_spectrum(&[1.0; 32]);
        assert!((mag[0] - 32.0).abs() < 1e-9);
        for &m in &mag[1..] {
            assert!(m < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        let mut re = vec![0.0; 12];
        let mut im = vec![0.0; 12];
        fft_inplace(&mut re, &mut im, false);
    }

    #[test]
    fn spectrogram_shape_and_determinism() {
        let sig: Vec<f64> = (0..256).map(|i| (i as f64 * 0.1).sin()).collect();
        let s1 = log_spectrogram(&sig, 64, 32);
        let s2 = log_spectrogram(&sig, 64, 32);
        assert_eq!(s1.len(), s2.len());
        assert_eq!(s1[0].len(), 33);
        assert!(s1.len() >= 6);
        assert_eq!(s1, s2);
    }

    #[test]
    fn hann_window_properties() {
        let w = hann(64);
        assert!(w[0] < 1e-12);
        assert!((w[32] - 1.0).abs() < 1e-3);
        assert!(w.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
