//! Fixed-size worker thread pool (substrate).
//!
//! The offline crate set has no tokio; the serving coordinator is built
//! on OS threads and mpsc channels instead (DESIGN.md section 3,
//! offline-crate substitutions). Provides `execute` for fire-and-forget
//! jobs and `parallel_map` for fork-join data parallelism.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("smoothcache-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Fork-join: apply `f` to every item, preserving order.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }

    /// Block until the queue is drained.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.parallel_map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn single_worker_is_sequential() {
        let pool = ThreadPool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..10 {
            let log = Arc::clone(&log);
            pool.execute(move || log.lock().unwrap().push(i));
        }
        pool.wait_idle();
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }
}
