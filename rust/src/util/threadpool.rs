//! Fixed-size worker thread pool (substrate).
//!
//! The offline crate set has no tokio or rayon; the serving coordinator
//! and the parallel GEMM substrate ([`crate::tensor::gemm`]) are built
//! on OS threads and mpsc channels instead (DESIGN.md section 3,
//! offline-crate substitutions). Provides `execute` for fire-and-forget
//! jobs, `parallel_map` for fork-join data parallelism over owned data,
//! and `scoped_map` for fork-join over borrowed data (the GEMM row-panel
//! hot path).
//!
//! Panic containment: a panicking job is caught on the worker, the
//! worker survives, and the pending-job counter is released by a drop
//! guard — so `wait_idle` and the fork-join drains never deadlock on a
//! poisoned queue. A fork-join caller still observes the failure: the
//! panic payload travels back over the result channel and is
//! `resume_unwind`-ed in the caller with its original message, *after*
//! every other job has drained.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on threads owned by any [`ThreadPool`]. Fork-join entry points
/// use this to degrade to inline execution instead of deadlocking: a
/// worker that blocked waiting on sub-jobs would occupy the very slot
/// those sub-jobs need.
pub fn on_worker_thread() -> bool {
    IS_POOL_WORKER.with(|c| c.get())
}

/// Decrements the pending counter even when the job unwinds.
struct PendingGuard(Arc<AtomicUsize>);

impl Drop for PendingGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

pub struct ThreadPool {
    /// Job submission side; `Mutex` keeps the pool `Sync` on every
    /// supported toolchain so a single pool can be shared by reference
    /// across executor replicas.
    tx: Option<Mutex<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("smoothcache-worker-{i}"))
                    .spawn(move || {
                        IS_POOL_WORKER.with(|c| c.set(true));
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            match job {
                                Ok(job) => {
                                    let _guard = PendingGuard(Arc::clone(&pending));
                                    // contain panics: the worker survives
                                    // and the guard releases `pending`
                                    let _ = catch_unwind(AssertUnwindSafe(job));
                                }
                                Err(_) => break, // sender dropped: shut down
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(Mutex::new(tx)), workers, pending }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Non-panicking enqueue. Fails only if the pool was shut down —
    /// impossible while a caller holds `&self`, but kept infallible so
    /// `scoped_map` can enforce its no-unwind window explicitly.
    fn try_submit(&self, job: Job) -> Result<(), ()> {
        let Some(tx) = self.tx.as_ref() else { return Err(()) };
        let guard = tx.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        self.pending.fetch_add(1, Ordering::SeqCst);
        guard.send(job).map_err(|_| {
            self.pending.fetch_sub(1, Ordering::SeqCst);
        })
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.try_submit(Box::new(f)).expect("pool shut down");
    }

    /// Fork-join over borrowed data: apply `f` to every item, preserving
    /// order. Called from a pool worker it runs inline (see
    /// [`on_worker_thread`]); otherwise items are fanned out to the
    /// workers and this call blocks until every job has completed or
    /// unwound — which is what makes lending `'env` borrows to the
    /// workers sound (see SAFETY below).
    pub fn scoped_map<'env, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'env,
        R: Send + 'env,
        F: Fn(T) -> R + Send + Sync + 'env,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if on_worker_thread() || self.size() == 1 || n == 1 {
            return items.into_iter().map(f).collect();
        }
        let f = Arc::new(f);
        type Outcome<R> = std::thread::Result<R>; // Ok(r) | Err(panic payload)
        let (tx, rx): (Sender<(usize, Outcome<R>)>, Receiver<(usize, Outcome<R>)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                // `item` is consumed inside the catch (dropped there even
                // on unwind) and the result — or the panic payload, so
                // the caller can resume it with context intact — moves
                // into the channel; when this closure's environment
                // drops, no borrow of `'env` data remains on the worker.
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
            // SAFETY: erasing `'env` to `'static` is sound because this
            // function does not return before (a) the receive loop below
            // has observed every sender clone dropping — so every job,
            // including panicked ones, has finished executing against the
            // borrowed data — and (b) the strong-count barrier after it
            // has observed every job's `Arc<F>` clone dropping — so no
            // worker is still running `F`'s (or its captures') destructor.
            // Nothing between the first enqueue and the barrier may
            // unwind: `try_submit` is non-panicking and failure aborts.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            if self.try_submit(job).is_err() {
                // queued 'env-erased jobs may already be running; an
                // unwind here would free their borrows under them
                eprintln!("threadpool: pool shut down with scoped jobs in flight; aborting");
                std::process::abort();
            }
        }
        drop(tx);
        let mut out: Vec<Option<Outcome<R>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        // Closure-capture drop order is unspecified: a worker may drop a
        // job's `tx` clone (disconnecting us above) *before* its `Arc<F>`
        // clone. Spin until every job-held clone is gone so no worker can
        // still be dropping `F` (whose destructor may touch `'env` data)
        // after we return. `T` items need no such barrier — they are
        // consumed (or unwound) inside the catch frame, strictly before
        // the job's `tx` clone drops.
        while Arc::strong_count(&f) > 1 {
            std::thread::yield_now();
        }
        // order the workers' drop effects before anything the caller
        // does with the reclaimed borrows
        std::sync::atomic::fence(Ordering::Acquire);
        let mut results = Vec::with_capacity(n);
        for slot in out {
            match slot.expect("scoped job vanished without reporting") {
                Ok(r) => results.push(r),
                // re-raise the first job panic with its original payload
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        results
    }

    /// Fork-join over owned data: apply `f` to every item, preserving
    /// order. (A `'static` specialization of [`ThreadPool::scoped_map`].)
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.scoped_map(items, f)
    }

    /// Block until the queue is drained.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.parallel_map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_borrows_caller_data() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..64).collect();
        let out = pool.scoped_map((0..data.len()).collect(), |i| data[i] * 2);
        assert_eq!(out, data.iter().map(|&x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_writes_through_disjoint_slices() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0u64; 40];
        let chunks: Vec<(usize, &mut [u64])> =
            buf.chunks_mut(10).enumerate().collect();
        pool.scoped_map(chunks, |(ci, chunk)| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 10 + j) as u64;
            }
        });
        assert_eq!(buf, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn single_worker_is_sequential() {
        let pool = ThreadPool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..10 {
            let log = Arc::clone(&log);
            pool.execute(move || log.lock().unwrap().push(i));
        }
        pool.wait_idle();
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    /// Satellite regression: a panicking job used to leave `pending`
    /// forever-incremented (the decrement sat *after* the call), so
    /// `wait_idle` deadlocked and the worker thread died. The drop guard
    /// plus `catch_unwind` keep the pool fully usable.
    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.execute(|| panic!("job goes boom"));
        }
        pool.wait_idle(); // must return, not spin forever
        assert_eq!(pool.pending(), 0);
        // both workers must still be alive and processing
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    /// A fork-join with one panicking item drains the others, then
    /// re-raises the *original* panic payload in the caller.
    #[test]
    fn scoped_map_reports_panicked_item_after_drain() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_map((0..8).collect::<Vec<usize>>(), |x| {
                if x == 3 {
                    panic!("poisoned item");
                }
                x * 2
            })
        }));
        let payload = result.expect_err("caller must observe the job panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "poisoned item", "original payload must survive");
        // the pool itself is unharmed
        let out = pool.scoped_map((0..8).collect::<Vec<usize>>(), |x| x + 1);
        assert_eq!(out, (1..9).collect::<Vec<usize>>());
    }

    #[test]
    fn nested_scoped_map_runs_inline() {
        // a job that fans out again must not deadlock: the inner map
        // detects the worker thread and degrades to inline execution
        let pool = Arc::new(ThreadPool::new(2));
        let p2 = Arc::clone(&pool);
        let out = pool.scoped_map(vec![10usize, 20, 30], move |x| {
            p2.scoped_map((0..x).collect(), |y: usize| y).len()
        });
        assert_eq!(out, vec![10, 20, 30]);
    }
}
