//! Generation-quality metrics.
//!
//! PSNR and SSIM are the literal metrics from the paper's Table 2
//! (SSIM in its global form — the universal quality index of Wang &
//! Bovik 2002, the paper's own citation [37]). The learned-network
//! metrics (FID/sFID/IS, LPIPS, CLAP, KL_PaSST, FD_OpenL3) are
//! unavailable offline; DESIGN.md §3 defines the proxies implemented
//! here — all built on a fixed, seeded random-projection feature space
//! so they are deterministic, model-free, and respond monotonically to
//! generation corruption:
//!
//! * **FFD** (Fréchet Feature Distance) ↔ FID / FD_OpenL3
//! * **LPIPS-proxy**: normalized feature-space distance ↔ LPIPS
//! * **IS-proxy**: inception-score formula over a random classifier head
//! * **KL-proxy** ↔ KL_PaSST
//! * **CLAP-proxy**: cosine similarity to the reference (no-cache)
//!   generation for the same prompt/seed ↔ prompt-adherence preservation

pub mod audio;
pub mod ssim2d;

pub use audio::{spectral_fd, spectral_features};
pub use ssim2d::ssim2d;

use crate::linalg::{covariance, frechet_distance_sq, mean_rows};
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Pixel metrics (exact)
// ---------------------------------------------------------------------------

/// PSNR in dB between two same-shape tensors; the dynamic range is taken
/// from the reference tensor (paper protocol: vs the non-cached output).
pub fn psnr(reference: &Tensor, test: &Tensor) -> f64 {
    assert_eq!(reference.shape, test.shape);
    let n = reference.len() as f64;
    let mse: f64 = reference
        .data
        .iter()
        .zip(&test.data)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n;
    let lo = reference.data.iter().cloned().fold(f32::MAX, f32::min) as f64;
    let hi = reference.data.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let range = (hi - lo).max(1e-9);
    if mse <= 0.0 {
        return f64::INFINITY;
    }
    10.0 * ((range * range) / mse).log10()
}

/// Global SSIM (universal quality index): luminance/contrast/structure
/// over whole-sample statistics.
pub fn ssim(reference: &Tensor, test: &Tensor) -> f64 {
    assert_eq!(reference.shape, test.shape);
    let mx = reference.mean();
    let my = test.mean();
    let vx = reference.var();
    let vy = test.var();
    let n = reference.len() as f64;
    let cov: f64 = reference
        .data
        .iter()
        .zip(&test.data)
        .map(|(&a, &b)| (a as f64 - mx) * (b as f64 - my))
        .sum::<f64>()
        / n;
    let lo = reference.data.iter().cloned().fold(f32::MAX, f32::min) as f64;
    let hi = reference.data.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let l = (hi - lo).max(1e-9);
    let c1 = (0.01 * l).powi(2);
    let c2 = (0.03 * l).powi(2);
    ((2.0 * mx * my + c1) * (2.0 * cov + c2)) / ((mx * mx + my * my + c1) * (vx + vy + c2))
}

// ---------------------------------------------------------------------------
// Reduced-precision output gating
// ---------------------------------------------------------------------------

/// Verdict of [`precision_gate`]: how close a reduced-precision
/// generation is to its f32 reference, and whether it clears the bar.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionGate {
    /// Structural similarity vs the reference: windowed [`ssim2d`] for
    /// rank-4 [N, H, W, C] latents, the global [`ssim`] otherwise.
    pub ssim: f64,
    /// Spectral Fréchet distance, only for rank-3 sets with >= 4
    /// samples (the audio-family shape); `None` elsewhere.
    pub spectral_fd: Option<f64>,
    /// The SSIM floor the gate was asked to hold.
    pub min_ssim: f64,
    /// `ssim >= min_ssim` (the spectral distance is reported, not
    /// thresholded — it has no universal scale across families).
    pub pass: bool,
}

/// Gate a reduced-precision output against the f32 reference for the
/// same request: computes the structural-similarity and (where the
/// shape supports it) spectral-distance metrics, and passes iff SSIM
/// holds `min_ssim`. This is the acceptance check behind the
/// `compute:` knob — see docs/adr/006 for the per-mode floors.
pub fn precision_gate(reference: &Tensor, test: &Tensor, min_ssim: f64) -> Result<PrecisionGate> {
    if reference.shape != test.shape {
        return Err(crate::err!(
            "precision_gate: shape mismatch {:?} vs {:?}",
            reference.shape,
            test.shape
        ));
    }
    if reference.is_empty() {
        return Err(crate::err!("precision_gate: empty tensors"));
    }
    if !min_ssim.is_finite() {
        return Err(crate::err!("precision_gate: min_ssim must be finite, got {min_ssim}"));
    }
    let s = if reference.rank() == 4 {
        ssim2d(reference, test)?
    } else {
        ssim(reference, test)
    };
    let spectral = (reference.rank() == 3 && reference.dim0() >= 4)
        .then(|| spectral_fd(reference, test, 64));
    Ok(PrecisionGate { ssim: s, spectral_fd: spectral, min_ssim, pass: s >= min_ssim })
}

// ---------------------------------------------------------------------------
// Fixed random feature space (the FID/LPIPS/IS substitution substrate)
// ---------------------------------------------------------------------------

/// Two-layer random projection with tanh nonlinearity:
/// feat = W2 · tanh(W1 · x / sqrt(n)). Deterministic given (seed, dims).
pub struct FeatureExtractor {
    seed: u64,
    pub dim: usize,
    hidden: usize,
    // lazily built per input size
    cache: std::cell::RefCell<std::collections::HashMap<usize, (Vec<f32>, Vec<f32>)>>,
}

impl FeatureExtractor {
    pub fn new(seed: u64, dim: usize) -> FeatureExtractor {
        FeatureExtractor { seed, dim, hidden: 2 * dim, cache: Default::default() }
    }

    fn weights_for(&self, n: usize) -> (Vec<f32>, Vec<f32>) {
        if let Some(w) = self.cache.borrow().get(&n) {
            return w.clone();
        }
        let mut rng = Rng::new(self.seed ^ (n as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let w1: Vec<f32> = (0..n * self.hidden)
            .map(|_| rng.normal_f32() / (n as f32).sqrt())
            .collect();
        let w2: Vec<f32> = (0..self.hidden * self.dim)
            .map(|_| rng.normal_f32() / (self.hidden as f32).sqrt())
            .collect();
        self.cache.borrow_mut().insert(n, (w1.clone(), w2.clone()));
        (w1, w2)
    }

    /// Features of one sample (any shape; flattened).
    pub fn features(&self, sample: &Tensor) -> Vec<f64> {
        let n = sample.len();
        let (w1, w2) = self.weights_for(n);
        let mut h = vec![0.0f32; self.hidden];
        for (i, &x) in sample.data.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let row = &w1[i * self.hidden..(i + 1) * self.hidden];
            for (hj, &w) in h.iter_mut().zip(row) {
                *hj += x * w;
            }
        }
        for v in &mut h {
            *v = v.tanh();
        }
        let mut out = vec![0.0f64; self.dim];
        for (j, &hv) in h.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let row = &w2[j * self.dim..(j + 1) * self.dim];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += (hv * w) as f64;
            }
        }
        out
    }

    /// Feature matrix (n_samples × dim, row-major) over a batch tensor.
    pub fn features_batch(&self, batch: &Tensor) -> Vec<f64> {
        let b = batch.dim0();
        let mut out = Vec::with_capacity(b * self.dim);
        for i in 0..b {
            out.extend(self.features(&batch.sample(i)));
        }
        out
    }
}

/// Fréchet Feature Distance between two sample sets (batch tensors).
pub fn ffd(fx: &FeatureExtractor, set_a: &Tensor, set_b: &Tensor) -> f64 {
    let fa = fx.features_batch(set_a);
    let fb = fx.features_batch(set_b);
    let (na, nb) = (set_a.dim0(), set_b.dim0());
    assert!(na >= 2 && nb >= 2, "FFD needs >= 2 samples per set");
    let mu_a = mean_rows(&fa, na, fx.dim);
    let mu_b = mean_rows(&fb, nb, fx.dim);
    let ca = covariance(&fa, na, fx.dim);
    let cb = covariance(&fb, nb, fx.dim);
    frechet_distance_sq(&mu_a, &ca, &mu_b, &cb).sqrt()
}

/// LPIPS-proxy: mean normalized feature-space L2 distance per pair
/// (paired samples, e.g. cached vs no-cache generations, same seeds).
pub fn lpips_proxy(fx: &FeatureExtractor, reference: &Tensor, test: &Tensor) -> f64 {
    assert_eq!(reference.dim0(), test.dim0());
    let b = reference.dim0();
    let mut total = 0.0;
    for i in 0..b {
        let fr = fx.features(&reference.sample(i));
        let ft = fx.features(&test.sample(i));
        let d2: f64 = fr.iter().zip(&ft).map(|(a, b)| (a - b) * (a - b)).sum();
        let nr: f64 = fr.iter().map(|x| x * x).sum::<f64>().max(1e-12);
        total += (d2 / nr).sqrt();
    }
    total / b as f64
}

/// CLAP-proxy: mean cosine similarity between the features of paired
/// samples (prompt-adherence preservation; 1.0 = identical content).
pub fn clap_proxy(fx: &FeatureExtractor, reference: &Tensor, test: &Tensor) -> f64 {
    assert_eq!(reference.dim0(), test.dim0());
    let b = reference.dim0();
    let mut total = 0.0;
    for i in 0..b {
        let fr = fx.features(&reference.sample(i));
        let ft = fx.features(&test.sample(i));
        let dot: f64 = fr.iter().zip(&ft).map(|(a, b)| a * b).sum();
        let na: f64 = fr.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = ft.iter().map(|x| x * x).sum::<f64>().sqrt();
        total += dot / (na * nb).max(1e-12);
    }
    total / b as f64
}

/// Class distribution of one sample under the fixed random classifier.
fn class_probs(fx: &FeatureExtractor, sample: &Tensor, classes: usize, seed: u64) -> Vec<f64> {
    let f = fx.features(sample);
    let mut rng = Rng::new(seed);
    let w: Vec<f64> = (0..fx.dim * classes).map(|_| rng.normal()).collect();
    let mut logits = vec![0.0f64; classes];
    for (i, &fv) in f.iter().enumerate() {
        for c in 0..classes {
            logits[c] += fv * w[i * classes + c];
        }
    }
    let mx = logits.iter().cloned().fold(f64::MIN, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - mx).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// IS-proxy: exp(E_x KL(p(y|x) ‖ p(y))) over the fixed random classifier.
pub fn is_proxy(fx: &FeatureExtractor, set: &Tensor, classes: usize) -> f64 {
    let b = set.dim0();
    let probs: Vec<Vec<f64>> =
        (0..b).map(|i| class_probs(fx, &set.sample(i), classes, fx.seed ^ 0xC1A55)).collect();
    let mut marginal = vec![0.0f64; classes];
    for p in &probs {
        for (m, &v) in marginal.iter_mut().zip(p) {
            *m += v / b as f64;
        }
    }
    let mut kl_sum = 0.0;
    for p in &probs {
        for (c, &v) in p.iter().enumerate() {
            if v > 1e-12 {
                kl_sum += v * (v / marginal[c].max(1e-12)).ln();
            }
        }
    }
    (kl_sum / b as f64).exp()
}

/// KL-proxy: mean KL between paired per-sample class distributions.
pub fn kl_proxy(fx: &FeatureExtractor, reference: &Tensor, test: &Tensor, classes: usize) -> f64 {
    assert_eq!(reference.dim0(), test.dim0());
    let b = reference.dim0();
    let mut total = 0.0;
    for i in 0..b {
        let p = class_probs(fx, &reference.sample(i), classes, fx.seed ^ 0xC1A55);
        let q = class_probs(fx, &test.sample(i), classes, fx.seed ^ 0xC1A55);
        for c in 0..classes {
            if p[c] > 1e-12 {
                total += p[c] * (p[c] / q[c].max(1e-12)).ln();
            }
        }
    }
    total / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy_copy(t: &Tensor, sigma: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        t.map(|v| v + sigma * rng.normal_f32())
    }

    fn random_set(b: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(vec![b, n], &mut rng)
    }

    #[test]
    fn psnr_identical_infinite_and_monotone() {
        let a = random_set(1, 256, 1);
        assert!(psnr(&a, &a).is_infinite());
        let p_small = psnr(&a, &noisy_copy(&a, 0.01, 2));
        let p_big = psnr(&a, &noisy_copy(&a, 0.2, 2));
        assert!(p_small > p_big, "{p_small} vs {p_big}");
        assert!(p_small > 20.0);
    }

    #[test]
    fn ssim_identical_is_one_and_monotone() {
        let a = random_set(1, 256, 3);
        assert!((ssim(&a, &a) - 1.0).abs() < 1e-9);
        let s_small = ssim(&a, &noisy_copy(&a, 0.05, 4));
        let s_big = ssim(&a, &noisy_copy(&a, 0.5, 4));
        assert!(s_small > s_big);
        assert!(s_big < 1.0);
    }

    #[test]
    fn features_deterministic() {
        let fx = FeatureExtractor::new(42, 16);
        let a = random_set(1, 64, 5);
        assert_eq!(fx.features(&a), fx.features(&a));
        let fx2 = FeatureExtractor::new(42, 16);
        assert_eq!(fx.features(&a), fx2.features(&a));
    }

    #[test]
    fn ffd_zero_for_same_distribution_and_grows_with_shift() {
        let fx = FeatureExtractor::new(7, 8);
        let a = random_set(64, 32, 10);
        let b = random_set(64, 32, 11);
        let base = ffd(&fx, &a, &b);
        // shifted distribution
        let shifted = b.map(|v| v + 2.0);
        let far = ffd(&fx, &a, &shifted);
        assert!(base < far, "{base} vs {far}");
    }

    #[test]
    fn ffd_monotone_in_noise() {
        let fx = FeatureExtractor::new(7, 8);
        let a = random_set(64, 32, 20);
        let d1 = ffd(&fx, &a, &noisy_copy(&a, 0.1, 21));
        let d2 = ffd(&fx, &a, &noisy_copy(&a, 1.0, 21));
        assert!(d1 < d2, "{d1} vs {d2}");
    }

    #[test]
    fn lpips_proxy_zero_identical_monotone() {
        let fx = FeatureExtractor::new(9, 16);
        let a = random_set(8, 64, 30);
        assert!(lpips_proxy(&fx, &a, &a) < 1e-9);
        let d1 = lpips_proxy(&fx, &a, &noisy_copy(&a, 0.05, 31));
        let d2 = lpips_proxy(&fx, &a, &noisy_copy(&a, 0.5, 31));
        assert!(d1 < d2);
    }

    #[test]
    fn clap_proxy_one_identical_decays() {
        let fx = FeatureExtractor::new(11, 16);
        let a = random_set(8, 64, 40);
        assert!((clap_proxy(&fx, &a, &a) - 1.0).abs() < 1e-9);
        let c1 = clap_proxy(&fx, &a, &noisy_copy(&a, 0.1, 41));
        let c2 = clap_proxy(&fx, &a, &noisy_copy(&a, 1.0, 41));
        assert!(c1 > c2);
    }

    #[test]
    fn is_proxy_higher_for_diverse_set() {
        let fx = FeatureExtractor::new(13, 16);
        // diverse: random; degenerate: one sample repeated
        let diverse = random_set(32, 64, 50);
        let one = diverse.sample(0);
        let degenerate = one.pad0_to(32);
        let is_div = is_proxy(&fx, &diverse, 10);
        let is_deg = is_proxy(&fx, &degenerate, 10);
        assert!(is_div > is_deg, "{is_div} vs {is_deg}");
        assert!((is_deg - 1.0).abs() < 1e-6);
    }

    #[test]
    fn precision_gate_passes_identical_and_fails_noisy() {
        let mut rng = Rng::new(70);
        let img = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        let g = precision_gate(&img, &img, 0.999).unwrap();
        assert!(g.pass);
        assert!((g.ssim - 1.0).abs() < 1e-9);
        assert_eq!(g.spectral_fd, None, "rank-4 has no spectral metric");
        // heavy noise must fail a high floor
        let noisy = noisy_copy(&img, 0.8, 71);
        let g = precision_gate(&img, &noisy, 0.99).unwrap();
        assert!(!g.pass, "ssim {} should be below 0.99", g.ssim);
    }

    #[test]
    fn precision_gate_picks_metric_by_rank() {
        // rank-2 falls back to the global ssim
        let a = random_set(1, 256, 72);
        let g = precision_gate(&a, &noisy_copy(&a, 0.01, 73), 0.5).unwrap();
        assert!(g.pass && g.spectral_fd.is_none());
        // rank-3 with >= 4 samples additionally reports spectral_fd
        let mut rng = Rng::new(74);
        let set = Tensor::randn(vec![4, 64, 2], &mut rng);
        let g = precision_gate(&set, &noisy_copy(&set, 0.01, 75), 0.5).unwrap();
        assert!(g.spectral_fd.is_some());
        assert!(g.spectral_fd.unwrap() >= 0.0);
    }

    #[test]
    fn precision_gate_rejects_malformed_inputs() {
        let a = random_set(1, 16, 76);
        let b = random_set(2, 16, 77);
        assert!(precision_gate(&a, &b, 0.9).is_err());
        let e = Tensor::zeros(vec![0]);
        assert!(precision_gate(&e, &e, 0.9).is_err());
        assert!(precision_gate(&a, &a, f64::NAN).is_err());
    }

    #[test]
    fn kl_proxy_zero_identical_monotone() {
        let fx = FeatureExtractor::new(15, 16);
        let a = random_set(8, 64, 60);
        assert!(kl_proxy(&fx, &a, &a, 10) < 1e-9);
        let k1 = kl_proxy(&fx, &a, &noisy_copy(&a, 0.1, 61), 10);
        let k2 = kl_proxy(&fx, &a, &noisy_copy(&a, 1.0, 61), 10);
        assert!(k1 < k2);
    }
}
