//! Audio-domain quality metrics over log-spectrogram features.
//!
//! FD_OpenL3 and KL_PaSST in the paper run learned audio networks over
//! time-frequency input. These proxies keep the "metric sees spectra"
//! structure: each audio latent channel is treated as a waveform, STFT'd
//! (util::fft), and summarised into a fixed-length spectral feature
//! vector; Fréchet / KL machinery is then identical to the paper's.

use crate::linalg::{covariance, frechet_distance_sq, mean_rows};
use crate::tensor::Tensor;
use crate::util::fft::log_spectrogram;

/// Spectral feature vector of one audio latent sample `[T, C]`:
/// per-channel mean + std of each spectrogram frequency band.
pub fn spectral_features(sample: &Tensor, n_fft: usize) -> Vec<f64> {
    assert_eq!(sample.rank(), 3, "expected [1, T, C]");
    let t = sample.shape[1];
    let c = sample.shape[2];
    let mut feats = Vec::new();
    for ch in 0..c {
        let wave: Vec<f64> = (0..t).map(|i| sample.data[i * c + ch] as f64).collect();
        let spec = log_spectrogram(&wave, n_fft, n_fft / 2);
        let bins = spec[0].len();
        for b in 0..bins {
            let vals: Vec<f64> = spec.iter().map(|f| f[b]).collect();
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            let v = vals.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / vals.len() as f64;
            feats.push(m);
            feats.push(v.sqrt());
        }
    }
    feats
}

/// Spectral feature matrix over a batch `[N, T, C]` (rows × dim).
pub fn spectral_features_batch(set: &Tensor, n_fft: usize) -> (Vec<f64>, usize) {
    let n = set.dim0();
    let mut rows = Vec::new();
    let mut dim = 0;
    for i in 0..n {
        let f = spectral_features(&set.sample(i), n_fft);
        dim = f.len();
        rows.extend(f);
    }
    (rows, dim)
}

/// Spectral Fréchet distance (FD_OpenL3 proxy) between two audio sets.
pub fn spectral_fd(set_a: &Tensor, set_b: &Tensor, n_fft: usize) -> f64 {
    let (fa, dim) = spectral_features_batch(set_a, n_fft);
    let (fb, _) = spectral_features_batch(set_b, n_fft);
    let (na, nb) = (set_a.dim0(), set_b.dim0());
    assert!(na >= 4 && nb >= 4, "spectral_fd needs >= 4 samples per set");
    // subsample the feature axis so the covariance stays well-conditioned
    // at bench sample counts (target dim << min(n_a, n_b))
    let target_d = (na.min(nb) / 2).clamp(4, 16);
    let stride = dim.div_ceil(target_d);
    let keep: Vec<usize> = (0..dim).step_by(stride).collect();
    let reduce = |rows: &[f64], n: usize| -> Vec<f64> {
        let mut out = Vec::with_capacity(n * keep.len());
        for r in 0..n {
            for &k in &keep {
                out.push(rows[r * dim + k]);
            }
        }
        out
    };
    let ra = reduce(&fa, na);
    let rb = reduce(&fb, nb);
    let d = keep.len();
    let mu_a = mean_rows(&ra, na, d);
    let mu_b = mean_rows(&rb, nb, d);
    let ca = covariance(&ra, na, d);
    let cb = covariance(&rb, nb, d);
    frechet_distance_sq(&mu_a, &ca, &mu_b, &cb).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn audio_set(n: usize, seed: u64, freq: f64) -> Tensor {
        let (t, c) = (64usize, 8usize);
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        for _ in 0..n {
            let phase = rng.range_f64(0.0, 6.28);
            for ti in 0..t {
                for ci in 0..c {
                    data.push(
                        ((freq * (ci + 1) as f64 * ti as f64 + phase).sin()
                            + 0.1 * rng.normal()) as f32,
                    );
                }
            }
        }
        Tensor::new(vec![n, t, c], data)
    }

    #[test]
    fn features_deterministic_and_sized() {
        let set = audio_set(2, 1, 0.3);
        let f1 = spectral_features(&set.sample(0), 32);
        let f2 = spectral_features(&set.sample(0), 32);
        assert_eq!(f1, f2);
        assert_eq!(f1.len(), 8 * 17 * 2); // C × bins × (mean, std)
    }

    #[test]
    fn fd_separates_frequencies() {
        let a1 = audio_set(24, 1, 0.3);
        let a2 = audio_set(24, 2, 0.3);
        let b = audio_set(24, 3, 0.9);
        let same = spectral_fd(&a1, &a2, 32);
        let diff = spectral_fd(&a1, &b, 32);
        assert!(same < diff, "same-freq {same} vs diff-freq {diff}");
    }

    #[test]
    fn fd_zero_for_identical() {
        let a = audio_set(24, 5, 0.5);
        assert!(spectral_fd(&a, &a, 32) < 1e-6);
    }
}
