//! Windowed 2-D SSIM (the standard image-domain formulation, gaussian
//! 7×7 window) — complements the global universal-quality-index form in
//! quality::ssim for image-family comparisons.

use crate::tensor::Tensor;

fn gaussian_kernel(radius: usize, sigma: f64) -> Vec<f64> {
    let size = 2 * radius + 1;
    let mut k = Vec::with_capacity(size * size);
    let mut sum = 0.0;
    for y in 0..size {
        for x in 0..size {
            let dy = y as f64 - radius as f64;
            let dx = x as f64 - radius as f64;
            let v = (-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp();
            k.push(v);
            sum += v;
        }
    }
    for v in &mut k {
        *v /= sum;
    }
    k
}

/// Gaussian-filtered local map (same size, clamped borders).
fn filter(img: &[f64], h: usize, w: usize, kernel: &[f64], radius: usize) -> Vec<f64> {
    let size = 2 * radius + 1;
    let mut out = vec![0.0; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0.0;
            for ky in 0..size {
                for kx in 0..size {
                    let sy = (y + ky).saturating_sub(radius).min(h - 1);
                    let sx = (x + kx).saturating_sub(radius).min(w - 1);
                    acc += kernel[ky * size + kx] * img[sy * w + sx];
                }
            }
            out[y * w + x] = acc;
        }
    }
    out
}

/// Windowed SSIM over a single-channel [H, W] plane pair.
pub fn ssim2d_plane(a: &[f64], b: &[f64], h: usize, w: usize) -> f64 {
    assert_eq!(a.len(), h * w);
    assert_eq!(b.len(), h * w);
    let radius = 3;
    let kernel = gaussian_kernel(radius, 1.5);
    let mu_a = filter(a, h, w, &kernel, radius);
    let mu_b = filter(b, h, w, &kernel, radius);
    let aa: Vec<f64> = a.iter().map(|v| v * v).collect();
    let bb: Vec<f64> = b.iter().map(|v| v * v).collect();
    let ab: Vec<f64> = a.iter().zip(b).map(|(x, y)| x * y).collect();
    let s_aa = filter(&aa, h, w, &kernel, radius);
    let s_bb = filter(&bb, h, w, &kernel, radius);
    let s_ab = filter(&ab, h, w, &kernel, radius);

    let lo = a.iter().chain(b).cloned().fold(f64::MAX, f64::min);
    let hi = a.iter().chain(b).cloned().fold(f64::MIN, f64::max);
    let l = (hi - lo).max(1e-9);
    let c1 = (0.01 * l).powi(2);
    let c2 = (0.03 * l).powi(2);

    let mut total = 0.0;
    for i in 0..h * w {
        let va = s_aa[i] - mu_a[i] * mu_a[i];
        let vb = s_bb[i] - mu_b[i] * mu_b[i];
        let cov = s_ab[i] - mu_a[i] * mu_b[i];
        total += ((2.0 * mu_a[i] * mu_b[i] + c1) * (2.0 * cov + c2))
            / ((mu_a[i] * mu_a[i] + mu_b[i] * mu_b[i] + c1) * (va + vb + c2));
    }
    total / (h * w) as f64
}

/// Windowed SSIM over [1, H, W, C] image latents, averaged across
/// channels; for batches, averaged across samples.
pub fn ssim2d(reference: &Tensor, test: &Tensor) -> f64 {
    assert_eq!(reference.shape, test.shape);
    assert_eq!(reference.rank(), 4, "expected [N, H, W, C]");
    let (n, h, w, c) =
        (reference.shape[0], reference.shape[1], reference.shape[2], reference.shape[3]);
    let mut total = 0.0;
    for s in 0..n {
        for ch in 0..c {
            let plane = |t: &Tensor| -> Vec<f64> {
                (0..h * w)
                    .map(|i| t.data[s * h * w * c + i * c + ch] as f64)
                    .collect()
            };
            total += ssim2d_plane(&plane(reference), &plane(test), h, w);
        }
    }
    total / (n * c) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_images_score_one() {
        let mut rng = Rng::new(1);
        let img = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        assert!((ssim2d(&img, &img) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn monotone_in_noise() {
        let mut rng = Rng::new(2);
        let img = Tensor::randn(vec![2, 16, 16, 4], &mut rng);
        let mut r1 = Rng::new(3);
        let small = img.map(|v| v + 0.05 * r1.normal_f32());
        let mut r2 = Rng::new(3);
        let big = img.map(|v| v + 0.8 * r2.normal_f32());
        let s1 = ssim2d(&img, &small);
        let s2 = ssim2d(&img, &big);
        assert!(s1 > s2, "{s1} vs {s2}");
        assert!(s2 < 0.9);
    }

    #[test]
    fn structural_shift_detected() {
        // constant image vs shifted-structure image: SSIM penalises
        // structure more than a uniform brightness change
        let h = 16;
        let base: Vec<f64> = (0..h * h)
            .map(|i| ((i / h) as f64 / h as f64 * 6.0).sin())
            .collect();
        let bright: Vec<f64> = base.iter().map(|v| v + 0.05).collect();
        let transposed: Vec<f64> = (0..h * h)
            .map(|i| base[(i % h) * h + i / h])
            .collect();
        let s_bright = ssim2d_plane(&base, &bright, h, h);
        let s_trans = ssim2d_plane(&base, &transposed, h, h);
        assert!(s_bright > s_trans);
    }

    #[test]
    fn gaussian_kernel_normalized() {
        let k = gaussian_kernel(3, 1.5);
        assert!((k.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(k.len(), 49);
    }
}
