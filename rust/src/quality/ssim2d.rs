//! Windowed 2-D SSIM (the standard image-domain formulation, gaussian
//! 7×7 window) — complements the global universal-quality-index form in
//! quality::ssim for image-family comparisons.
//!
//! Degenerate inputs are typed errors, not silent numbers: a zero
//! height/width used to fall through to a `0/0` mean (NaN scores that
//! poisoned downstream gates) and, for the border clamp, an `h - 1`
//! underflow. One-pixel dimensions are valid — the gaussian window
//! pins to the image edge (every tap clamps onto the single row or
//! column), which the tests pin explicitly.

use crate::tensor::Tensor;
use crate::util::error::Result;

fn gaussian_kernel(radius: usize, sigma: f64) -> Vec<f64> {
    let size = 2 * radius + 1;
    let mut k = Vec::with_capacity(size * size);
    let mut sum = 0.0;
    for y in 0..size {
        for x in 0..size {
            let dy = y as f64 - radius as f64;
            let dx = x as f64 - radius as f64;
            let v = (-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp();
            k.push(v);
            sum += v;
        }
    }
    for v in &mut k {
        *v /= sum;
    }
    k
}

/// Gaussian-filtered local map (same size, clamped borders).
fn filter(img: &[f64], h: usize, w: usize, kernel: &[f64], radius: usize) -> Vec<f64> {
    let size = 2 * radius + 1;
    let mut out = vec![0.0; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0.0;
            for ky in 0..size {
                for kx in 0..size {
                    let sy = (y + ky).saturating_sub(radius).min(h - 1);
                    let sx = (x + kx).saturating_sub(radius).min(w - 1);
                    acc += kernel[ky * size + kx] * img[sy * w + sx];
                }
            }
            out[y * w + x] = acc;
        }
    }
    out
}

/// Windowed SSIM over a single-channel [H, W] plane pair. Errors on
/// zero-sized planes (one-pixel dimensions are fine: the window clamps
/// to the edge).
pub fn ssim2d_plane(a: &[f64], b: &[f64], h: usize, w: usize) -> Result<f64> {
    if h == 0 || w == 0 {
        return Err(crate::err!("ssim2d: degenerate plane {h}x{w} (both dims must be >= 1)"));
    }
    if a.len() != h * w || b.len() != h * w {
        return Err(crate::err!(
            "ssim2d: plane length mismatch: {h}x{w} needs {} values, got {} and {}",
            h * w,
            a.len(),
            b.len()
        ));
    }
    let radius = 3;
    let kernel = gaussian_kernel(radius, 1.5);
    let mu_a = filter(a, h, w, &kernel, radius);
    let mu_b = filter(b, h, w, &kernel, radius);
    let aa: Vec<f64> = a.iter().map(|v| v * v).collect();
    let bb: Vec<f64> = b.iter().map(|v| v * v).collect();
    let ab: Vec<f64> = a.iter().zip(b).map(|(x, y)| x * y).collect();
    let s_aa = filter(&aa, h, w, &kernel, radius);
    let s_bb = filter(&bb, h, w, &kernel, radius);
    let s_ab = filter(&ab, h, w, &kernel, radius);

    let lo = a.iter().chain(b).cloned().fold(f64::MAX, f64::min);
    let hi = a.iter().chain(b).cloned().fold(f64::MIN, f64::max);
    let l = (hi - lo).max(1e-9);
    let c1 = (0.01 * l).powi(2);
    let c2 = (0.03 * l).powi(2);

    let mut total = 0.0;
    for i in 0..h * w {
        let va = s_aa[i] - mu_a[i] * mu_a[i];
        let vb = s_bb[i] - mu_b[i] * mu_b[i];
        let cov = s_ab[i] - mu_a[i] * mu_b[i];
        total += ((2.0 * mu_a[i] * mu_b[i] + c1) * (2.0 * cov + c2))
            / ((mu_a[i] * mu_a[i] + mu_b[i] * mu_b[i] + c1) * (va + vb + c2));
    }
    Ok(total / (h * w) as f64)
}

/// Windowed SSIM over [N, H, W, C] image latents, averaged across
/// channels; for batches, averaged across samples. Errors on shape
/// mismatch, non-rank-4 input and zero-sized dimensions.
pub fn ssim2d(reference: &Tensor, test: &Tensor) -> Result<f64> {
    if reference.shape != test.shape {
        return Err(crate::err!(
            "ssim2d: shape mismatch {:?} vs {:?}",
            reference.shape,
            test.shape
        ));
    }
    if reference.rank() != 4 {
        return Err(crate::err!("ssim2d: expected rank-4 [N, H, W, C], got {:?}", reference.shape));
    }
    let (n, h, w, c) =
        (reference.shape[0], reference.shape[1], reference.shape[2], reference.shape[3]);
    if n == 0 || c == 0 {
        return Err(crate::err!("ssim2d: degenerate batch/channel dims in {:?}", reference.shape));
    }
    let mut total = 0.0;
    for s in 0..n {
        for ch in 0..c {
            let plane = |t: &Tensor| -> Vec<f64> {
                (0..h * w)
                    .map(|i| t.data[s * h * w * c + i * c + ch] as f64)
                    .collect()
            };
            total += ssim2d_plane(&plane(reference), &plane(test), h, w)?;
        }
    }
    Ok(total / (n * c) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_images_score_one() {
        let mut rng = Rng::new(1);
        let img = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        assert!((ssim2d(&img, &img).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn monotone_in_noise() {
        let mut rng = Rng::new(2);
        let img = Tensor::randn(vec![2, 16, 16, 4], &mut rng);
        let mut r1 = Rng::new(3);
        let small = img.map(|v| v + 0.05 * r1.normal_f32());
        let mut r2 = Rng::new(3);
        let big = img.map(|v| v + 0.8 * r2.normal_f32());
        let s1 = ssim2d(&img, &small).unwrap();
        let s2 = ssim2d(&img, &big).unwrap();
        assert!(s1 > s2, "{s1} vs {s2}");
        assert!(s2 < 0.9);
    }

    #[test]
    fn structural_shift_detected() {
        // constant image vs shifted-structure image: SSIM penalises
        // structure more than a uniform brightness change
        let h = 16;
        let base: Vec<f64> = (0..h * h)
            .map(|i| ((i / h) as f64 / h as f64 * 6.0).sin())
            .collect();
        let bright: Vec<f64> = base.iter().map(|v| v + 0.05).collect();
        let transposed: Vec<f64> = (0..h * h)
            .map(|i| base[(i % h) * h + i / h])
            .collect();
        let s_bright = ssim2d_plane(&base, &bright, h, h).unwrap();
        let s_trans = ssim2d_plane(&base, &transposed, h, h).unwrap();
        assert!(s_bright > s_trans);
    }

    #[test]
    fn degenerate_dims_are_typed_errors_not_nan() {
        // a zero-sized plane used to produce a silent 0/0 = NaN score
        let err = ssim2d_plane(&[], &[], 0, 5).unwrap_err();
        assert!(format!("{err}").contains("ssim2d"), "{err}");
        assert!(ssim2d_plane(&[], &[], 5, 0).is_err());
        // mismatched plane lengths are caught too
        assert!(ssim2d_plane(&[1.0; 4], &[1.0; 3], 2, 2).is_err());
        // tensor form: zero batch/channel/spatial dims all error
        for shape in [vec![0, 4, 4, 1], vec![1, 0, 4, 1], vec![1, 4, 0, 1], vec![1, 4, 4, 0]] {
            let t = Tensor::zeros(shape.clone());
            assert!(ssim2d(&t, &t).is_err(), "{shape:?} must be rejected");
        }
        // shape mismatch and wrong rank are errors, not panics
        let a = Tensor::zeros(vec![1, 4, 4, 1]);
        let b = Tensor::zeros(vec![1, 4, 5, 1]);
        assert!(ssim2d(&a, &b).is_err());
        assert!(ssim2d(&Tensor::zeros(vec![4, 4]), &Tensor::zeros(vec![4, 4])).is_err());
    }

    #[test]
    fn one_pixel_dims_pin_the_window_to_the_edge() {
        // every gaussian tap clamps onto the single row/column, so the
        // local stats degenerate to exact per-pixel stats: identical
        // planes score exactly 1 and no index underflows
        let col: Vec<f64> = (0..8).map(|i| i as f64 * 0.3 - 1.0).collect();
        assert!((ssim2d_plane(&col, &col, 8, 1).unwrap() - 1.0).abs() < 1e-9);
        assert!((ssim2d_plane(&col, &col, 1, 8).unwrap() - 1.0).abs() < 1e-9);
        let px = [0.7];
        assert!((ssim2d_plane(&px, &px, 1, 1).unwrap() - 1.0).abs() < 1e-9);
        // and a perturbed single column still scores below identical
        let noisy: Vec<f64> = col.iter().map(|v| v + 0.4 * (v * 7.0).sin()).collect();
        assert!(ssim2d_plane(&col, &noisy, 8, 1).unwrap() < 1.0);
        // tensor form with 1-pixel spatial dims works end to end
        let t = Tensor::new(vec![1, 1, 8, 1], col.iter().map(|&v| v as f32).collect());
        assert!((ssim2d(&t, &t).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gaussian_kernel_normalized() {
        let k = gaussian_kernel(3, 1.5);
        assert!((k.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(k.len(), 49);
    }
}
