//! Static caching schedules: the object SmoothCache produces offline and
//! the pipeline consumes at inference time.
//!
//! A schedule assigns, for every solver step and branch type, either
//! `Compute` (run the branch executables and refill the cache) or
//! `Reuse { filled_at }` (skip the PJRT executions; re-inject the cached
//! deltas through the residual connection — paper Fig. 3). Decisions are
//! grouped by *branch type* across block depth, exactly as §2.2
//! motivates (mitigating cascaded approximation error); the grouping
//! ablation relaxes this to per-site decisions.

use crate::util::error::Result;

use crate::util::json::{parse, Json};

/// What one (step, branch) site does at inference time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// run the branch executables and refill the layer cache.
    Compute,
    /// skip execution; re-inject the delta cached at an earlier step.
    Reuse {
        /// the step whose computed delta is re-injected. Invariant
        /// ([`Schedule::validate`]): strictly in the past, computed,
        /// and the *latest* compute before this step.
        filled_at: usize,
    },
}

impl Decision {
    /// `true` for [`Decision::Compute`].
    pub fn is_compute(&self) -> bool {
        matches!(self, Decision::Compute)
    }
}

/// Schedule over (step, branch-type). `decisions[step][bt]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// human-readable policy name (`no-cache`, `fora-n2`,
    /// `smoothcache-a0.35`, …) used in bench tables.
    pub name: String,
    /// solver steps the schedule spans.
    pub steps: usize,
    /// branch-type column order of `decisions`.
    pub branch_types: Vec<String>,
    /// `decisions[step][bt]`; invariants in [`Schedule::validate`].
    pub decisions: Vec<Vec<Decision>>,
}

impl Schedule {
    /// All-compute (the "No Cache" row of every paper table).
    pub fn no_cache(steps: usize, branch_types: &[String]) -> Schedule {
        Schedule {
            name: "no-cache".into(),
            steps,
            branch_types: branch_types.to_vec(),
            decisions: vec![vec![Decision::Compute; branch_types.len()]; steps],
        }
    }

    /// FORA-style uniform static caching: compute on every n-th step,
    /// reuse otherwise (paper baseline; n=2,3 in Table 1).
    pub fn fora(steps: usize, branch_types: &[String], n: usize) -> Schedule {
        assert!(n >= 1);
        let mut s = Schedule::no_cache(steps, branch_types);
        s.name = format!("fora-n{n}");
        for step in 0..steps {
            if step % n != 0 {
                let filled = step - step % n;
                for d in &mut s.decisions[step] {
                    *d = Decision::Reuse { filled_at: filled };
                }
            }
        }
        s
    }

    /// L2C-proxy: cache every other step (the "learned alternate-step
    /// policy" shape; its 2× ceiling is inherent — see DESIGN.md §3).
    pub fn alternate(steps: usize, branch_types: &[String]) -> Schedule {
        let mut s = Schedule::fora(steps, branch_types, 2);
        s.name = "alternate".into();
        s
    }

    /// Number of branch-type columns.
    pub fn n_branch_types(&self) -> usize {
        self.branch_types.len()
    }

    /// The decision at (step, branch type); panics on an unknown type.
    pub fn decision(&self, step: usize, branch_type: &str) -> Decision {
        let bt = self
            .branch_types
            .iter()
            .position(|b| b == branch_type)
            .unwrap_or_else(|| panic!("unknown branch type {branch_type}"));
        self.decisions[step][bt]
    }

    /// Fraction of branch evaluations skipped (the paper's headline
    /// compute-saving knob).
    pub fn skip_fraction(&self) -> f64 {
        let total = self.steps * self.branch_types.len();
        if total == 0 {
            return 0.0;
        }
        let skipped = self
            .decisions
            .iter()
            .flatten()
            .filter(|d| !d.is_compute())
            .count();
        skipped as f64 / total as f64
    }

    /// Compute-count per branch type (for MAC accounting).
    pub fn computes_per_type(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.branch_types.len()];
        for row in &self.decisions {
            for (i, d) in row.iter().enumerate() {
                if d.is_compute() {
                    counts[i] += 1;
                }
            }
        }
        counts
    }

    /// Structural invariants every valid schedule satisfies. Property
    /// tests drive random generators through this.
    pub fn validate(&self) -> Result<()> {
        if self.decisions.len() != self.steps {
            return Err(crate::err!("decision rows {} != steps {}", self.decisions.len(), self.steps));
        }
        for (step, row) in self.decisions.iter().enumerate() {
            if row.len() != self.branch_types.len() {
                return Err(crate::err!("step {step}: row width mismatch"));
            }
            for (bt, d) in row.iter().enumerate() {
                if let Decision::Reuse { filled_at } = d {
                    if step == 0 {
                        return Err(crate::err!("step 0 must compute (cache empty)"));
                    }
                    if *filled_at >= step {
                        return Err(crate::err!(
                            "step {step}/{}: filled_at {filled_at} not in the past",
                            self.branch_types[bt]
                        ));
                    }
                    if !self.decisions[*filled_at][bt].is_compute() {
                        return Err(crate::err!(
                            "step {step}/{}: filled_at {filled_at} was not computed",
                            self.branch_types[bt]
                        ));
                    }
                    // the fill must be the *latest* compute before `step`
                    for mid in (*filled_at + 1)..step {
                        if self.decisions[mid][bt].is_compute() {
                            return Err(crate::err!(
                                "step {step}/{}: stale reuse (computed at {mid} after fill {filled_at})",
                                self.branch_types[bt]
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Largest reuse gap in the schedule.
    pub fn max_gap(&self) -> usize {
        let mut g = 0;
        for (step, row) in self.decisions.iter().enumerate() {
            for d in row {
                if let Decision::Reuse { filled_at } = d {
                    g = g.max(step - filled_at);
                }
            }
        }
        g
    }

    // ---- JSON round-trip ----------------------------------------------------

    /// Serialise (compute = -1, reuse = the fill step).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .decisions
            .iter()
            .map(|row| {
                Json::Arr(
                    row.iter()
                        .map(|d| match d {
                            Decision::Compute => Json::Num(-1.0),
                            Decision::Reuse { filled_at } => Json::Num(*filled_at as f64),
                        })
                        .collect(),
                )
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("steps", self.steps)
            .set("branch_types", self.branch_types.iter().map(|s| Json::Str(s.clone())).collect::<Vec<_>>())
            .set("decisions", Json::Arr(rows))
    }

    /// Deserialise and [`Schedule::validate`] a schedule.
    pub fn from_json(j: &Json) -> Result<Schedule> {
        let name = j.req("name")?.as_str().unwrap_or("schedule").to_string();
        let steps = j.req("steps")?.as_usize().ok_or_else(|| crate::err!("steps"))?;
        let branch_types: Vec<String> = j
            .req("branch_types")?
            .as_arr()
            .ok_or_else(|| crate::err!("branch_types"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut decisions = Vec::with_capacity(steps);
        for (si, row) in j
            .req("decisions")?
            .as_arr()
            .ok_or_else(|| crate::err!("decisions"))?
            .iter()
            .enumerate()
        {
            let mut out_row = Vec::new();
            for v in row.as_arr().ok_or_else(|| crate::err!("decision row"))? {
                // a non-numeric cell used to silently fall back to the
                // -1.0 Compute sentinel, turning a corrupt schedule into
                // a quietly slower one
                let n = v.as_f64().ok_or_else(|| {
                    crate::err!(
                        "schedule json: decision at step {si} must be a number \
                         (-1 = compute, N = fill step), got {}",
                        v.to_string()
                    )
                })?;
                out_row.push(if n < 0.0 {
                    Decision::Compute
                } else {
                    Decision::Reuse { filled_at: n as usize }
                });
            }
            decisions.push(out_row);
        }
        let s = Schedule { name, steps, branch_types, decisions };
        s.validate()?;
        Ok(s)
    }

    /// Parse a schedule from JSON text (see [`Schedule::to_json`]).
    pub fn parse_str(text: &str) -> Result<Schedule> {
        Schedule::from_json(&parse(text).map_err(|e| crate::err!("schedule json: {e}"))?)
    }

    /// Compact visual: one line per branch type, `#` compute / `.` reuse.
    pub fn ascii(&self) -> String {
        let mut out = String::new();
        for (bt, name) in self.branch_types.iter().enumerate() {
            out.push_str(&format!("{name:>10} "));
            for row in &self.decisions {
                out.push(if row[bt].is_compute() { '#' } else { '.' });
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bts() -> Vec<String> {
        vec!["attn".into(), "ffn".into()]
    }

    #[test]
    fn no_cache_all_compute() {
        let s = Schedule::no_cache(10, &bts());
        assert_eq!(s.skip_fraction(), 0.0);
        s.validate().unwrap();
        assert_eq!(s.computes_per_type(), vec![10, 10]);
    }

    #[test]
    fn fora_n2_skips_half() {
        let s = Schedule::fora(10, &bts(), 2);
        s.validate().unwrap();
        assert!((s.skip_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(s.decision(0, "attn"), Decision::Compute);
        assert_eq!(s.decision(1, "attn"), Decision::Reuse { filled_at: 0 });
        assert_eq!(s.decision(2, "attn"), Decision::Compute);
        assert_eq!(s.max_gap(), 1);
    }

    #[test]
    fn fora_n3_structure() {
        let s = Schedule::fora(9, &bts(), 3);
        s.validate().unwrap();
        assert_eq!(s.decision(4, "ffn"), Decision::Reuse { filled_at: 3 });
        assert_eq!(s.decision(5, "ffn"), Decision::Reuse { filled_at: 3 });
        assert!((s.skip_fraction() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_gap(), 2);
    }

    #[test]
    fn fora_n1_equals_no_cache() {
        let s = Schedule::fora(7, &bts(), 1);
        assert_eq!(s.skip_fraction(), 0.0);
    }

    #[test]
    fn validate_rejects_step0_reuse() {
        let mut s = Schedule::no_cache(3, &bts());
        s.decisions[0][0] = Decision::Reuse { filled_at: 0 };
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_future_fill() {
        let mut s = Schedule::no_cache(3, &bts());
        s.decisions[1][0] = Decision::Reuse { filled_at: 2 };
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_stale_reuse() {
        let mut s = Schedule::no_cache(4, &bts());
        // compute at 0, 1, 2; reuse at 3 pointing past a newer compute
        s.decisions[3][0] = Decision::Reuse { filled_at: 1 };
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_reuse_of_noncomputed() {
        let mut s = Schedule::no_cache(4, &bts());
        s.decisions[1][0] = Decision::Reuse { filled_at: 0 };
        s.decisions[2][0] = Decision::Reuse { filled_at: 1 }; // 1 was a reuse
        assert!(s.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let s = Schedule::fora(20, &bts(), 3);
        let back = Schedule::parse_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn non_numeric_decision_is_a_typed_error() {
        // a corrupt cell used to silently deserialise as Compute,
        // masking schedule corruption as a slower-but-valid plan
        let good = Schedule::fora(4, &bts(), 2).to_json().to_string();
        for replacement in [r#""compute""#, "null", "{}"] {
            // first decision row is [-1, -1] (step 0 computes everything)
            let bad = good.replacen("-1", replacement, 1);
            assert_ne!(bad, good);
            let err = Schedule::parse_str(&bad).unwrap_err();
            assert!(format!("{err}").contains("decision"), "{replacement}: {err}");
        }
    }

    #[test]
    fn ascii_render() {
        let s = Schedule::fora(4, &bts(), 2);
        let a = s.ascii();
        assert!(a.contains("#.#."));
    }
}
