//! Additional caching baselines beyond FORA / alternate.
//!
//! * [`delta_dit`] — a δ-DiT-style depth-aware baseline (related-work
//!   [4]): in the early, structure-forming phase of sampling the *back*
//!   half of the block stack is cached; in the late, detail-forming
//!   phase the *front* half is — while the other half recomputes every
//!   n-th step like FORA. It exercises the per-site decision machinery
//!   the grouping ablation also uses.

use std::collections::BTreeMap;

use super::schedule::Decision;

/// Build a per-site δ-DiT-like decision map.
///
/// `boundary` ∈ (0, 1): fraction of steps considered the "early" phase.
/// Within the cached half, outputs refresh every `n` steps.
pub fn delta_dit(
    steps: usize,
    depth: usize,
    branch_types: &[String],
    n: usize,
    boundary: f64,
) -> BTreeMap<String, Vec<Decision>> {
    assert!(n >= 1 && steps >= 1 && depth >= 1);
    let split = depth / 2;
    let boundary_step = ((steps as f64) * boundary).round() as usize;
    let mut out = BTreeMap::new();
    for block in 0..depth {
        for bt in branch_types {
            let mut ds = vec![Decision::Compute; steps];
            let mut last_fill = 0usize;
            for s in 1..steps {
                let early = s < boundary_step;
                let in_cached_half = if early { block >= split } else { block < split };
                if in_cached_half && (s - last_fill) < n {
                    ds[s] = Decision::Reuse { filled_at: last_fill };
                } else {
                    ds[s] = Decision::Compute;
                    last_fill = s;
                }
            }
            out.insert(format!("{block}.{bt}"), ds);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bts() -> Vec<String> {
        vec!["attn".into(), "ffn".into()]
    }

    #[test]
    fn structure_respects_phase_split() {
        let m = delta_dit(10, 4, &bts(), 2, 0.5);
        assert_eq!(m.len(), 8);
        // early phase (s=1): back half (blocks 2,3) reuses, front computes
        assert!(!m["3.attn"][1].is_compute());
        assert!(m["0.attn"][1].is_compute());
        // late phase (s=6): front half reuses, back computes
        assert!(!m["0.attn"][7].is_compute());
        assert!(m["3.attn"][7].is_compute());
    }

    #[test]
    fn refresh_interval_bounds_gap() {
        let m = delta_dit(20, 4, &bts(), 3, 0.5);
        for ds in m.values() {
            assert!(ds[0].is_compute());
            for (s, d) in ds.iter().enumerate() {
                if let Decision::Reuse { filled_at } = d {
                    assert!(s - filled_at < 3);
                    assert!(ds[*filled_at].is_compute());
                }
            }
        }
    }

    #[test]
    fn n1_means_no_caching() {
        let m = delta_dit(10, 2, &bts(), 1, 0.5);
        for ds in m.values() {
            assert!(ds.iter().all(|d| d.is_compute()));
        }
    }
}
