//! Layer-representation error curves (paper Fig. 2) and the SmoothCache
//! schedule generator (paper Eq. 4).
//!
//! For layer type `i` at solver step index `s` (steps run in execution
//! order; larger index = later = smaller diffusion t) and gap `k`, the
//! curve stores the L1 relative error between the branch outputs at step
//! `s` and step `s−k`:
//!
//!   E_i(s, k) = mean_{j, samples} ‖L_{i_j,s} − L_{i_j,s−k}‖₁ / ‖L_{i_j,s}‖₁
//!
//! averaged over block depth `j` (the paper's grouping) with the
//! across-sample spread kept for the 95% CI of Fig. 2. Per-site curves
//! (no depth averaging) are kept too for the grouping ablation.

use std::collections::BTreeMap;

use crate::util::error::Result;

use super::schedule::{Decision, Schedule};
use crate::util::json::{parse, Json};

/// Streaming mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Acc {
    /// number of observations pushed.
    pub n: u64,
    /// running mean of the observations.
    pub mean: f64,
    m2: f64,
}

impl Acc {
    /// Fold one observation into the running mean/variance.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Unbiased sample variance (0 with fewer than two observations).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Half-width of the 95% CI of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Error curves for one (family, solver, steps) calibration run.
#[derive(Clone, Debug)]
pub struct ErrorCurves {
    /// model family the curves were calibrated on.
    pub family: String,
    /// solver name (the schedule is trajectory-specific).
    pub solver: String,
    /// sampling steps of the calibrated configuration.
    pub steps: usize,
    /// maximum reuse gap recorded.
    pub k_max: usize,
    /// calibration samples accumulated so far.
    pub num_samples: usize,
    /// grouped over depth: branch type → `[steps][k_max]` accumulators;
    /// entry `[s][k-1]` is E(s, k), defined for s ≥ k (else n == 0).
    pub grouped: BTreeMap<String, Vec<Vec<Acc>>>,
    /// per-site: "block.branch" → same layout (grouping ablation).
    pub per_site: BTreeMap<String, Vec<Vec<Acc>>>,
}

impl ErrorCurves {
    /// Empty curves for a configuration (all accumulators at n = 0).
    pub fn new(
        family: &str,
        solver: &str,
        steps: usize,
        k_max: usize,
        branch_types: &[String],
        depth: usize,
    ) -> ErrorCurves {
        let blank = vec![vec![Acc::default(); k_max]; steps];
        let mut grouped = BTreeMap::new();
        let mut per_site = BTreeMap::new();
        for bt in branch_types {
            grouped.insert(bt.clone(), blank.clone());
            for b in 0..depth {
                per_site.insert(format!("{b}.{bt}"), blank.clone());
            }
        }
        ErrorCurves {
            family: family.into(),
            solver: solver.into(),
            steps,
            k_max,
            num_samples: 0,
            grouped,
            per_site,
        }
    }

    /// Record one observed pairwise error for (branch type, block, step, gap).
    pub fn record(&mut self, branch_type: &str, block: usize, step: usize, k: usize, err: f64) {
        debug_assert!(k >= 1 && k <= self.k_max && step >= k);
        self.grouped.get_mut(branch_type).expect("branch type")[step][k - 1].push(err);
        self.per_site.get_mut(&format!("{block}.{branch_type}")).expect("site")[step][k - 1]
            .push(err);
    }

    /// Mean error for (branch type, step, gap k).
    pub fn mean(&self, branch_type: &str, step: usize, k: usize) -> Option<f64> {
        let acc = &self.grouped.get(branch_type)?[step][k - 1];
        if acc.n == 0 {
            None
        } else {
            Some(acc.mean)
        }
    }

    /// Mean error for a per-site curve (`"block.branch"`) at (step, k).
    pub fn site_mean(&self, site: &str, step: usize, k: usize) -> Option<f64> {
        let acc = &self.per_site.get(site)?[step][k - 1];
        if acc.n == 0 {
            None
        } else {
            Some(acc.mean)
        }
    }

    /// Branch types the grouped curves cover, in sorted order.
    pub fn branch_types(&self) -> Vec<String> {
        self.grouped.keys().cloned().collect()
    }

    /// Mean across-sample CI width for a branch type at k=1 (the paper's
    /// observed predictor of the pareto-front width, §3.3 / §4).
    pub fn mean_ci_width(&self, branch_type: &str) -> f64 {
        let rows = &self.grouped[branch_type];
        let mut tot = 0.0;
        let mut n = 0;
        for (s, row) in rows.iter().enumerate() {
            if s >= 1 && row[0].n > 0 {
                tot += row[0].ci95();
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            tot / n as f64
        }
    }

    // -----------------------------------------------------------------------
    // SmoothCache schedule generation (paper Eq. 4)
    // -----------------------------------------------------------------------

    /// Greedy thresholding: at step s, branch type i is reused from the
    /// last computed step f iff the calibrated error E_i(s, s−f) < alpha
    /// and the gap stays ≤ k_max. Decisions are grouped across depth.
    pub fn smoothcache_schedule(&self, alpha: f64, branch_types_order: &[String]) -> Schedule {
        let mut decisions = vec![vec![Decision::Compute; branch_types_order.len()]; self.steps];
        for (bt_idx, bt) in branch_types_order.iter().enumerate() {
            let mut last_fill = 0usize;
            for s in 1..self.steps {
                let gap = s - last_fill;
                let reuse = gap <= self.k_max
                    && self
                        .mean(bt, s, gap)
                        .map(|e| e < alpha)
                        .unwrap_or(false);
                if reuse {
                    decisions[s][bt_idx] = Decision::Reuse { filled_at: last_fill };
                } else {
                    decisions[s][bt_idx] = Decision::Compute;
                    last_fill = s;
                }
            }
        }
        let s = Schedule {
            name: format!("smoothcache-a{alpha}"),
            steps: self.steps,
            branch_types: branch_types_order.to_vec(),
            decisions,
        };
        debug_assert!(s.validate().is_ok());
        s
    }

    /// Grouping ablation: independent per-(block, branch) decisions from
    /// the per-site curves. Returns per-site decision map keyed
    /// "block.branch" (the pipeline's per-site mode consumes this).
    pub fn per_site_schedule(&self, alpha: f64) -> BTreeMap<String, Vec<Decision>> {
        let mut out = BTreeMap::new();
        for (site, rows) in &self.per_site {
            let mut ds = vec![Decision::Compute; self.steps];
            let mut last_fill = 0usize;
            for s in 1..self.steps {
                let gap = s - last_fill;
                let reuse = gap <= self.k_max
                    && rows[s][gap - 1].n > 0
                    && rows[s][gap - 1].mean < alpha;
                if reuse {
                    ds[s] = Decision::Reuse { filled_at: last_fill };
                } else {
                    last_fill = s;
                }
            }
            out.insert(site.clone(), ds);
        }
        out
    }

    /// Find the alpha whose schedule skip-fraction is closest to the
    /// target (the paper's "matched TMACs" comparison rows).
    pub fn alpha_for_skip_fraction(
        &self,
        target: f64,
        branch_types_order: &[String],
    ) -> (f64, Schedule) {
        let mut lo = 0.0f64;
        let mut hi = 4.0f64;
        // skip fraction is monotone non-decreasing in alpha → bisection
        for _ in 0..48 {
            let mid = 0.5 * (lo + hi);
            let s = self.smoothcache_schedule(mid, branch_types_order);
            if s.skip_fraction() < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let s = self.smoothcache_schedule(hi, branch_types_order);
        (hi, s)
    }

    // ---- JSON persistence ---------------------------------------------------

    /// Serialise the curves (counts, means, stds) for on-disk caching.
    pub fn to_json(&self) -> Json {
        let ser_curves = |m: &BTreeMap<String, Vec<Vec<Acc>>>| {
            Json::Obj(
                m.iter()
                    .map(|(k, rows)| {
                        let rj: Vec<Json> = rows
                            .iter()
                            .map(|row| {
                                Json::Arr(
                                    row.iter()
                                        .map(|a| {
                                            Json::Arr(vec![
                                                Json::Num(a.n as f64),
                                                Json::Num(a.mean),
                                                Json::Num(a.std()),
                                            ])
                                        })
                                        .collect(),
                                )
                            })
                            .collect();
                        (k.clone(), Json::Arr(rj))
                    })
                    .collect(),
            )
        };
        Json::obj()
            .set("family", self.family.as_str())
            .set("solver", self.solver.as_str())
            .set("steps", self.steps)
            .set("k_max", self.k_max)
            .set("num_samples", self.num_samples)
            .set("grouped", ser_curves(&self.grouped))
            .set("per_site", ser_curves(&self.per_site))
    }

    /// Parse curves serialised by [`ErrorCurves::to_json`] (variance is
    /// reconstructed from the stored std — lossy but sufficient).
    pub fn parse_str(text: &str) -> Result<ErrorCurves> {
        let j = parse(text).map_err(|e| crate::err!("curves json: {e}"))?;
        let de_curves = |v: &Json| -> Result<BTreeMap<String, Vec<Vec<Acc>>>> {
            let mut m = BTreeMap::new();
            for (k, rows) in v.as_obj().ok_or_else(|| crate::err!("curves obj"))? {
                let mut out_rows = Vec::new();
                for row in rows.as_arr().ok_or_else(|| crate::err!("rows"))? {
                    let mut accs = Vec::new();
                    for a in row.as_arr().ok_or_else(|| crate::err!("row"))? {
                        let triple = a.as_f64_vec().ok_or_else(|| crate::err!("acc"))?;
                        let n = triple[0] as u64;
                        let mean = triple[1];
                        let std = triple[2];
                        // reconstruct m2 from std (lossy but sufficient)
                        let m2 = if n >= 2 { std * std * (n - 1) as f64 } else { 0.0 };
                        accs.push(Acc { n, mean, m2 });
                    }
                    out_rows.push(accs);
                }
                m.insert(k.clone(), out_rows);
            }
            Ok(m)
        };
        // Identity and provenance fields must be real values: a
        // non-string family/solver used to default to "" (a curve set
        // that silently matched no plan-store key) and a malformed
        // num_samples to 0 (reported as an uncalibrated artifact).
        Ok(ErrorCurves {
            family: j
                .req("family")?
                .as_str()
                .ok_or_else(|| crate::err!("curves json: family must be a string"))?
                .into(),
            solver: j
                .req("solver")?
                .as_str()
                .ok_or_else(|| crate::err!("curves json: solver must be a string"))?
                .into(),
            steps: j.req("steps")?.as_usize().ok_or_else(|| crate::err!("steps"))?,
            k_max: j.req("k_max")?.as_usize().ok_or_else(|| crate::err!("k_max"))?,
            num_samples: j
                .req("num_samples")?
                .as_usize()
                .ok_or_else(|| crate::err!("curves json: num_samples must be an integer"))?,
            grouped: de_curves(j.req("grouped")?)?,
            per_site: de_curves(j.req("per_site")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bts() -> Vec<String> {
        vec!["attn".into(), "ffn".into()]
    }

    /// Synthetic curves: attn error grows with step, ffn error constant.
    fn synthetic() -> ErrorCurves {
        let mut c = ErrorCurves::new("test", "ddim", 10, 3, &bts(), 2);
        for s in 1..10 {
            for k in 1..=3.min(s) {
                for b in 0..2 {
                    c.record("attn", b, s, k, 0.02 * s as f64 * k as f64);
                    c.record("ffn", b, s, k, 0.05 * k as f64);
                }
            }
        }
        c.num_samples = 1;
        c
    }

    #[test]
    fn welford_acc_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut a = Acc::default();
        for &x in &xs {
            a.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 4.0;
        assert!((a.mean - mean).abs() < 1e-12);
        assert!((a.var() - var).abs() < 1e-12);
        assert!(a.ci95() > 0.0);
    }

    #[test]
    fn record_and_query() {
        let c = synthetic();
        assert!((c.mean("attn", 5, 1).unwrap() - 0.1).abs() < 1e-12);
        assert!((c.mean("ffn", 5, 2).unwrap() - 0.1).abs() < 1e-12);
        assert!(c.mean("attn", 0, 1).is_none()); // step 0 has no past
        assert!((c.site_mean("0.attn", 5, 1).unwrap() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn schedule_threshold_behaviour() {
        let c = synthetic();
        // alpha below all errors → everything computes
        let s0 = c.smoothcache_schedule(0.0, &bts());
        assert_eq!(s0.skip_fraction(), 0.0);
        // huge alpha → max skipping bounded by k_max
        let s1 = c.smoothcache_schedule(100.0, &bts());
        s1.validate().unwrap();
        assert!(s1.max_gap() <= 3);
        assert!(s1.skip_fraction() > 0.5);
    }

    #[test]
    fn schedule_adapts_to_curve_shape() {
        let c = synthetic();
        // alpha = 0.07: ffn k=1 error (0.05) passes; attn passes only
        // early steps (0.02·s < 0.07 → s ≤ 3)
        let s = c.smoothcache_schedule(0.07, &bts());
        s.validate().unwrap();
        // attn: step 1 (err 0.02) reuses; step 2 from fill 0 (gap-2 err
        // 0.08) must compute; step 3 (gap-1 err 0.06) reuses again
        assert_eq!(s.decision(1, "attn"), Decision::Reuse { filled_at: 0 });
        assert!(s.decision(2, "attn").is_compute());
        assert_eq!(s.decision(3, "attn"), Decision::Reuse { filled_at: 2 });
        // late attn steps exceed alpha even at gap 1 (err 0.02·s ≥ 0.07)
        assert!(s.decision(8, "attn").is_compute());
        // ffn alternates forever: gap-1 err 0.05 < 0.07 but gap-2 err
        // 0.10 > 0.07 (step-size-independent curve)
        assert_eq!(s.decision(7, "ffn"), Decision::Reuse { filled_at: 6 });
        assert!(s.decision(8, "ffn").is_compute());
    }

    #[test]
    fn skip_fraction_monotone_in_alpha() {
        let c = synthetic();
        let mut prev = -1.0;
        for alpha in [0.0, 0.03, 0.06, 0.1, 0.2, 0.5] {
            let f = c.smoothcache_schedule(alpha, &bts()).skip_fraction();
            assert!(f >= prev, "alpha={alpha}: {f} < {prev}");
            prev = f;
        }
    }

    #[test]
    fn alpha_for_skip_fraction_hits_target() {
        let c = synthetic();
        let (alpha, s) = c.alpha_for_skip_fraction(0.4, &bts());
        assert!(alpha > 0.0);
        // monotone bisection: hit or slightly exceed the target
        assert!(s.skip_fraction() >= 0.4 - 1e-9);
        assert!(s.skip_fraction() <= 0.75);
    }

    #[test]
    fn per_site_schedules_valid_gaps() {
        let c = synthetic();
        let m = c.per_site_schedule(0.07);
        assert_eq!(m.len(), 4); // 2 blocks × 2 types
        for ds in m.values() {
            assert!(ds[0].is_compute());
            for (s, d) in ds.iter().enumerate() {
                if let Decision::Reuse { filled_at } = d {
                    assert!(s - filled_at <= 3);
                    assert!(ds[*filled_at].is_compute());
                }
            }
        }
    }

    #[test]
    fn json_roundtrip_preserves_means() {
        let c = synthetic();
        let back = ErrorCurves::parse_str(&c.to_json().to_string()).unwrap();
        assert_eq!(back.steps, c.steps);
        assert_eq!(back.k_max, c.k_max);
        for bt in ["attn", "ffn"] {
            for s in 1..10 {
                assert!(
                    (back.mean(bt, s, 1).unwrap() - c.mean(bt, s, 1).unwrap()).abs() < 1e-9
                );
            }
        }
        // schedules generated from the round-tripped curves are identical
        assert_eq!(
            back.smoothcache_schedule(0.07, &bts()),
            c.smoothcache_schedule(0.07, &bts())
        );
        // provenance fields survive the round trip verbatim
        assert_eq!(back.family, "test");
        assert_eq!(back.solver, "ddim");
        assert_eq!(back.num_samples, 1);
    }

    #[test]
    fn parse_rejects_malformed_identity_fields() {
        // family/solver used to silently default to "" and num_samples
        // to 0 on type mismatches — each is now a typed error naming
        // the field
        let good = synthetic().to_json().to_string();
        for (needle, replacement, field) in [
            (r#""family":"test""#, r#""family":7"#, "family"),
            (r#""solver":"ddim""#, r#""solver":["ddim"]"#, "solver"),
            (r#""num_samples":1"#, r#""num_samples":"many""#, "num_samples"),
        ] {
            let bad = good.replace(needle, replacement);
            assert_ne!(bad, good, "replacement {needle:?} did not apply");
            let err = ErrorCurves::parse_str(&bad).unwrap_err();
            assert!(format!("{err}").contains(field), "{field}: {err}");
        }
        // missing fields stay errors too
        let missing = good.replace(r#""family":"test","#, "");
        assert!(ErrorCurves::parse_str(&missing).is_err());
    }
}
