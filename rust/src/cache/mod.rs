//! SmoothCache core: error curves, calibration, and schedule generation.
//!
//! The paper's contribution, end to end:
//! 1. [`calibrator::calibrate`] — one no-cache calibration pass over a
//!    few samples, accumulating cross-timestep L1 relative error curves
//!    per branch type (paper Fig. 2, Eq. 4 LHS).
//! 2. [`curves::ErrorCurves::smoothcache_schedule`] — greedy α-threshold
//!    schedule generation (paper Eq. 4).
//! 3. [`schedule::Schedule`] — the grouped-by-branch-type artifact the
//!    schedule generator emits; baselines (FORA, alternate/L2C-proxy,
//!    no-cache) are constructors on the same type so every bench
//!    compares like with like.
//! 4. [`plan::CachePlan`] — the canonical *resolved* policy: one dense
//!    `[steps × sites]` decision matrix the pipeline executes, produced
//!    by [`plan::Planner`]s from the policy registry
//!    ([`plan::registry`]); runtime-adaptive policies plug in through
//!    [`plan::StepPlanner`].
#![deny(missing_docs)]

pub mod calibrator;
pub mod curves;
pub mod plan;
pub mod policies;
pub mod schedule;

pub use calibrator::{calibrate, paper_protocol, sample_cond, CalibrationConfig};
pub use curves::{Acc, ErrorCurves};
pub use plan::{
    parse_policy, registry, registry_markdown_rows, CachePlan, PlanCtx, PlanRef, Planner,
    PolicySpec, StepObs, StepPlanner,
};
pub use policies::delta_dit;
pub use schedule::{Decision, Schedule};

#[cfg(test)]
mod prop_tests {
    //! Property-based invariants over the schedule machinery (the mini
    //! propcheck framework stands in for proptest offline).

    use super::*;
    use crate::util::propcheck::{forall, gen};
    use crate::util::rng::Rng;

    fn random_curves(r: &mut Rng) -> (ErrorCurves, Vec<String>) {
        let steps = gen::usize_in(r, 2, 40);
        let k_max = gen::usize_in(r, 1, 6);
        let n_types = gen::usize_in(r, 1, 4);
        let bts: Vec<String> = (0..n_types).map(|i| format!("bt{i}")).collect();
        let depth = gen::usize_in(r, 1, 4);
        let mut c = ErrorCurves::new("t", "ddim", steps, k_max, &bts, depth);
        for bt in &bts {
            for s in 1..steps {
                for k in 1..=k_max.min(s) {
                    for b in 0..depth {
                        c.record(bt, b, s, k, gen::f64_in(r, 0.0, 1.0));
                    }
                }
            }
        }
        c.num_samples = 1;
        (c, bts)
    }

    /// Any (curves, alpha) yields a structurally valid schedule whose
    /// reuse gaps never exceed k_max.
    #[test]
    fn prop_smoothcache_schedules_always_valid() {
        let mut rng = Rng::new(0xC0DE);
        for _ in 0..120 {
            let (c, bts) = random_curves(&mut rng);
            for alpha in [0.0, 0.1, 0.5, 1.0, 2.0] {
                let s = c.smoothcache_schedule(alpha, &bts);
                s.validate().expect("valid schedule");
                assert!(s.max_gap() <= c.k_max);
            }
        }
    }

    /// skip_fraction is monotone non-decreasing in alpha for any curves.
    #[test]
    fn prop_skip_fraction_monotone_in_alpha() {
        let mut rng = Rng::new(0xA1FA);
        for _ in 0..60 {
            let (c, bts) = random_curves(&mut rng);
            let mut prev = -1.0;
            for i in 0..=10 {
                let alpha = i as f64 * 0.2;
                let f = c.smoothcache_schedule(alpha, &bts).skip_fraction();
                assert!(f + 1e-12 >= prev, "alpha={alpha} f={f} prev={prev}");
                prev = f;
            }
        }
    }

    /// FORA schedules validate for any (steps, n) and skip exactly
    /// floor-fraction of steps.
    #[test]
    fn prop_fora_always_valid() {
        forall(
            0xF0AA,
            200,
            |r| (gen::usize_in(r, 1, 200), gen::usize_in(r, 1, 10)),
            |&(steps, n): &(usize, usize)| {
                let bts = vec!["a".to_string(), "b".to_string()];
                let s = Schedule::fora(steps, &bts, n);
                s.validate().map_err(|e| e.to_string())?;
                let computes = (0..steps).filter(|i| i % n == 0).count();
                if s.computes_per_type() != vec![computes; 2] {
                    return Err("compute count mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// JSON round-trip preserves any valid schedule exactly.
    #[test]
    fn prop_schedule_json_roundtrip() {
        let mut rng = Rng::new(0x10AD);
        for _ in 0..60 {
            let (c, bts) = random_curves(&mut rng);
            let alpha = rng.range_f64(0.0, 1.2);
            let s = c.smoothcache_schedule(alpha, &bts);
            let back = Schedule::parse_str(&s.to_json().to_string()).unwrap();
            assert_eq!(s, back);
        }
    }

    /// Per-site schedules respect gap bounds and step-0 rule for any curves.
    #[test]
    fn prop_per_site_valid() {
        let mut rng = Rng::new(0x517E);
        for _ in 0..60 {
            let (c, _bts) = random_curves(&mut rng);
            let m = c.per_site_schedule(rng.range_f64(0.0, 1.2));
            for ds in m.values() {
                assert!(ds[0].is_compute());
                for (s, d) in ds.iter().enumerate() {
                    if let Decision::Reuse { filled_at } = d {
                        assert!(*filled_at < s);
                        assert!(s - filled_at <= c.k_max);
                        assert!(ds[*filled_at].is_compute());
                    }
                }
            }
        }
    }
}
