//! The first-class caching-policy surface: dense [`CachePlan`]s, the
//! [`Planner`] trait that produces them, the [`StepPlanner`] hook for
//! runtime-adaptive policies, and the policy *registry* every layer
//! (CLI, server wire format, coordinator lanes, benches) consumes.
//!
//! The paper's mechanism is "resolve a policy to per-(step, site)
//! compute/reuse decisions, then execute them". Historically the repo
//! spelled that object three ways (a grouped [`Schedule`], a
//! stringly-keyed per-site `BTreeMap`, and a `no-cache` special case),
//! forcing every consumer to triple-match. A [`CachePlan`] is the one
//! canonical form: a `[steps × sites]` decision matrix with sites
//! enumerated once from the family manifest, indexed by
//! `(step, site_idx)` with an O(1) flat-array lookup — no string keys,
//! no per-step allocation on the generate hot path.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::util::error::Result;

use super::curves::ErrorCurves;
use super::schedule::{Decision, Schedule};
use crate::model::FamilyManifest;
use crate::solvers::SolverKind;

// ---------------------------------------------------------------------------
// CachePlan — the dense decision matrix
// ---------------------------------------------------------------------------

/// One resolved caching policy: a dense `[steps × sites]` matrix of
/// [`Decision`]s over the family's (block, branch) sites in execution
/// order. This is the single artifact the pipeline executes; every
/// static policy (no-cache, FORA, alternate, SmoothCache grouped or
/// per-site, δ-DiT) resolves to one.
#[derive(Clone, Debug, PartialEq)]
pub struct CachePlan {
    /// human-readable policy name (`no-cache`, `fora-n2`,
    /// `smoothcache-a0.35`, …) used in bench tables and renders.
    pub name: String,
    /// solver steps the plan spans (matrix rows).
    pub steps: usize,
    /// `(block, branch-type)` sites in execution order (matrix columns);
    /// must equal [`FamilyManifest::branch_sites`] of the family the
    /// plan executes on ([`CachePlan::validate_for`]).
    pub sites: Vec<(usize, String)>,
    /// row-major `[steps × sites]` decisions.
    decisions: Vec<Decision>,
}

impl CachePlan {
    /// Construct from a raw decision matrix **without validating** —
    /// callers (tests, random generators) should run
    /// [`CachePlan::validate`] themselves. `decisions` is row-major by
    /// step: entry `(step, site)` lives at `step * sites.len() + site`.
    pub fn from_decisions(
        name: &str,
        steps: usize,
        sites: Vec<(usize, String)>,
        decisions: Vec<Decision>,
    ) -> CachePlan {
        CachePlan { name: name.into(), steps, sites, decisions }
    }

    /// All-compute plan (the "No Cache" rows; also what calibration
    /// trajectories execute).
    pub fn no_cache(steps: usize, sites: &[(usize, String)]) -> CachePlan {
        CachePlan {
            name: "no-cache".into(),
            steps,
            sites: sites.to_vec(),
            decisions: vec![Decision::Compute; steps * sites.len()],
        }
    }

    /// Expand a grouped-by-branch-type [`Schedule`] (the paper's
    /// decision shape) over concrete sites. Errors if a site's branch
    /// type is missing from the schedule or the result is invalid.
    pub fn from_grouped(schedule: &Schedule, sites: &[(usize, String)]) -> Result<CachePlan> {
        let mut cols = Vec::with_capacity(sites.len());
        for (_, bt) in sites {
            let idx = schedule
                .branch_types
                .iter()
                .position(|b| b == bt)
                .ok_or_else(|| {
                    crate::err!("schedule {:?} lacks branch type {bt:?}", schedule.name)
                })?;
            cols.push(idx);
        }
        let mut decisions = Vec::with_capacity(schedule.steps * sites.len());
        for row in &schedule.decisions {
            for &c in &cols {
                decisions.push(row[c]);
            }
        }
        let plan = CachePlan {
            name: schedule.name.clone(),
            steps: schedule.steps,
            sites: sites.to_vec(),
            decisions,
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Build a plan from a per-site decision map keyed `"block.branch"`
    /// (the shape the grouping ablation and δ-DiT produce). The site
    /// set must match `sites` **exactly** — a map built for a different
    /// family (missing or extra sites, wrong step count) is rejected
    /// loudly instead of silently defaulting unmatched sites to
    /// `Compute`.
    pub fn from_site_map(
        name: &str,
        steps: usize,
        sites: &[(usize, String)],
        map: &BTreeMap<String, Vec<Decision>>,
    ) -> Result<CachePlan> {
        if map.len() != sites.len() {
            let expected: std::collections::BTreeSet<String> =
                sites.iter().map(|(b, t)| format!("{b}.{t}")).collect();
            let got: std::collections::BTreeSet<String> = map.keys().cloned().collect();
            let missing: Vec<&String> = expected.difference(&got).collect();
            let extra: Vec<&String> = got.difference(&expected).collect();
            return Err(crate::err!(
                "plan {name:?}: site-set mismatch ({} sites expected, {} given; \
                 missing {missing:?}, extra {extra:?})",
                sites.len(),
                map.len()
            ));
        }
        let mut decisions = vec![Decision::Compute; steps * sites.len()];
        for (s_idx, (b, t)) in sites.iter().enumerate() {
            let key = format!("{b}.{t}");
            let ds = map.get(&key).ok_or_else(|| {
                crate::err!("plan {name:?}: per-site map missing site {key:?}")
            })?;
            if ds.len() != steps {
                return Err(crate::err!(
                    "plan {name:?}: site {key:?} has {} decisions for {steps} steps",
                    ds.len()
                ));
            }
            for (step, d) in ds.iter().enumerate() {
                decisions[step * sites.len() + s_idx] = *d;
            }
        }
        let plan =
            CachePlan { name: name.into(), steps, sites: sites.to_vec(), decisions };
        plan.validate()?;
        Ok(plan)
    }

    /// Number of (block, branch) sites (matrix columns).
    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    /// The decision at `(step, site_idx)` — one flat-array read, the
    /// generate loop's entire per-site scheduling cost.
    #[inline]
    pub fn decision(&self, step: usize, site: usize) -> Decision {
        self.decisions[step * self.sites.len() + site]
    }

    /// `"block.branch"` label of a site column (renders, errors).
    pub fn site_name(&self, site: usize) -> String {
        let (b, t) = &self.sites[site];
        format!("{b}.{t}")
    }

    /// Structural invariants every valid plan satisfies (the same rules
    /// [`Schedule::validate`] enforces, applied per site): the matrix
    /// is exactly `steps × sites`; step 0 computes (the cache is
    /// empty); every reuse points at the *latest* computed step
    /// strictly in its past.
    pub fn validate(&self) -> Result<()> {
        let n = self.sites.len();
        if self.decisions.len() != self.steps * n {
            return Err(crate::err!(
                "plan {:?}: {} decisions for {} steps x {n} sites",
                self.name,
                self.decisions.len(),
                self.steps
            ));
        }
        for site in 0..n {
            for step in 0..self.steps {
                if let Decision::Reuse { filled_at } = self.decision(step, site) {
                    let label = self.site_name(site);
                    if step == 0 {
                        return Err(crate::err!(
                            "plan {:?}: step 0 must compute at {label} (cache empty)",
                            self.name
                        ));
                    }
                    if filled_at >= step {
                        return Err(crate::err!(
                            "plan {:?}: step {step}/{label}: filled_at {filled_at} not in the past",
                            self.name
                        ));
                    }
                    if !self.decision(filled_at, site).is_compute() {
                        return Err(crate::err!(
                            "plan {:?}: step {step}/{label}: filled_at {filled_at} was not computed",
                            self.name
                        ));
                    }
                    for mid in (filled_at + 1)..step {
                        if self.decision(mid, site).is_compute() {
                            return Err(crate::err!(
                                "plan {:?}: step {step}/{label}: stale reuse \
                                 (computed at {mid} after fill {filled_at})",
                                self.name
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Check this plan matches an execution configuration: the step
    /// count and the family's site enumeration. Rejects plans built for
    /// a different family loudly (site-set mismatch), mirroring what
    /// the grouped path has always done for step/branch-type
    /// mismatches.
    pub fn validate_for(&self, fm: &FamilyManifest, steps: usize) -> Result<()> {
        if self.steps != steps {
            return Err(crate::err!(
                "plan {:?} has {} steps, request has {steps}",
                self.name,
                self.steps
            ));
        }
        let expected = fm.branch_sites();
        if self.sites != expected {
            return Err(crate::err!(
                "plan {:?} sites do not match family {:?} ({} plan sites vs {} family sites)",
                self.name,
                fm.name,
                self.sites.len(),
                expected.len()
            ));
        }
        Ok(())
    }

    /// Fraction of branch evaluations skipped (the paper's headline
    /// compute-saving knob).
    pub fn skip_fraction(&self) -> f64 {
        if self.decisions.is_empty() {
            return 0.0;
        }
        let skipped = self.decisions.iter().filter(|d| !d.is_compute()).count();
        skipped as f64 / self.decisions.len() as f64
    }

    /// Largest reuse gap in the plan.
    pub fn max_gap(&self) -> usize {
        let n = self.sites.len();
        let mut g = 0;
        for (i, d) in self.decisions.iter().enumerate() {
            if let Decision::Reuse { filled_at } = d {
                g = g.max(i / n - filled_at);
            }
        }
        g
    }

    /// Total computed branch evaluations across the plan.
    pub fn computes_total(&self) -> usize {
        self.decisions.iter().filter(|d| d.is_compute()).count()
    }

    /// Compact visual: one line per site, `#` compute / `.` reuse.
    pub fn ascii(&self) -> String {
        let mut out = String::new();
        for site in 0..self.sites.len() {
            out.push_str(&format!("{:>12} ", self.site_name(site)));
            for step in 0..self.steps {
                out.push(if self.decision(step, site).is_compute() { '#' } else { '.' });
            }
            out.push('\n');
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Planner — policy → plan
// ---------------------------------------------------------------------------

/// Everything a [`Planner`] may consult when resolving a policy to a
/// [`CachePlan`] for one (family, solver, steps) configuration.
pub struct PlanCtx<'a> {
    /// family geometry: site enumeration, branch types, depth.
    pub family: &'a FamilyManifest,
    /// solver of the configuration (plans from calibrated curves are
    /// trajectory-specific).
    pub solver: SolverKind,
    /// sampling steps the plan must span.
    pub steps: usize,
    /// calibrated error curves for the configuration; `Some` exactly
    /// when the policy's [`Planner::needs_curves`] is true (the store
    /// calibrates or loads them before calling [`Planner::plan`]).
    pub curves: Option<&'a ErrorCurves>,
}

/// A caching policy: resolves to a static [`CachePlan`], or exposes a
/// [`StepPlanner`] for runtime-adaptive decisions. Implementations are
/// registered in [`registry`] and reached through
/// [`parse_policy`] — the one table the CLI, the server wire format,
/// the coordinator's lane choice, and the benches all consume.
pub trait Planner: Send + Sync {
    /// Canonical wire string ([`parse_policy`] round-trips it).
    fn wire(&self) -> String;

    /// True when [`Planner::plan`] requires calibrated
    /// [`PlanCtx::curves`]. Such policies may pay a cold calibration on
    /// first use — the coordinator routes them to the work queue's
    /// normal lane until their curves are hot.
    fn needs_curves(&self) -> bool {
        false
    }

    /// Resolve the policy to a static plan for one configuration.
    /// Dynamic policies (where [`Planner::dynamic`] returns `Some`)
    /// have no static plan and error here.
    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan>;

    /// Runtime-adaptive hook: `Some` when decisions are made per
    /// (step, site) from runtime observations instead of a
    /// precomputed matrix.
    fn dynamic(&self) -> Option<&dyn StepPlanner> {
        None
    }
}

// ---------------------------------------------------------------------------
// StepPlanner — runtime-adaptive policies
// ---------------------------------------------------------------------------

/// What the pipeline knows about one site when a dynamic policy
/// decides. The pipeline owns all per-run state (cache fills, observed
/// drift), so [`StepPlanner::decide`] can stay pure — decisions are
/// deterministic functions of the trajectory, which keeps dynamic
/// policies bitwise reproducible across thread counts and replicas.
#[derive(Clone, Copy, Debug)]
pub struct StepObs {
    /// step at which this site's cached delta was computed
    /// (`None` = cold cache; the decision must be `Compute`).
    pub filled_at: Option<usize>,
    /// relative L1 drift measured at this site's most recent compute
    /// against the delta it replaced (`None` until the site has
    /// computed twice).
    pub last_drift: Option<f64>,
}

/// Per-(step, site) decision maker for runtime-adaptive policies.
pub trait StepPlanner: Send + Sync {
    /// Policy name for stats and renders.
    fn name(&self) -> &str;

    /// Decide what `(step, site)` does given the runtime observation.
    /// Contract: must return `Compute` when `obs.filled_at` is `None`
    /// (the pipeline rejects an impossible `Reuse` loudly).
    fn decide(&self, step: usize, site: usize, obs: &StepObs) -> Decision;
}

/// What the generate loop executes: a dense precomputed [`CachePlan`]
/// (static policies) or a [`StepPlanner`] deciding at runtime.
#[derive(Clone, Copy)]
pub enum PlanRef<'a> {
    /// every (step, site) decision precomputed.
    Plan(&'a CachePlan),
    /// decisions made per (step, site) from runtime observations.
    Planner(&'a dyn StepPlanner),
}

impl<'a> From<&'a CachePlan> for PlanRef<'a> {
    fn from(p: &'a CachePlan) -> PlanRef<'a> {
        PlanRef::Plan(p)
    }
}

// ---------------------------------------------------------------------------
// Concrete planners
// ---------------------------------------------------------------------------

/// `no-cache`: every branch computes at every step.
struct NoCachePlanner;

impl Planner for NoCachePlanner {
    fn wire(&self) -> String {
        "no-cache".into()
    }

    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan> {
        Ok(CachePlan::no_cache(ctx.steps, &ctx.family.branch_sites()))
    }
}

/// `fora:N`: compute on every N-th step, reuse otherwise.
struct ForaPlanner {
    n: usize,
}

impl Planner for ForaPlanner {
    fn wire(&self) -> String {
        format!("fora:{}", self.n)
    }

    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan> {
        let s = Schedule::fora(ctx.steps, &ctx.family.branch_types, self.n);
        CachePlan::from_grouped(&s, &ctx.family.branch_sites())
    }
}

/// `alternate`: cache every other step (L2C proxy).
struct AlternatePlanner;

impl Planner for AlternatePlanner {
    fn wire(&self) -> String {
        "alternate".into()
    }

    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan> {
        let s = Schedule::alternate(ctx.steps, &ctx.family.branch_types);
        CachePlan::from_grouped(&s, &ctx.family.branch_sites())
    }
}

/// `smooth:ALPHA`: the paper's grouped α-threshold schedule.
struct SmoothPlanner {
    alpha: f64,
}

impl Planner for SmoothPlanner {
    fn wire(&self) -> String {
        format!("smooth:{}", self.alpha)
    }

    fn needs_curves(&self) -> bool {
        true
    }

    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan> {
        let curves = ctx
            .curves
            .ok_or_else(|| crate::err!("smooth:{} needs calibrated curves", self.alpha))?;
        let s = curves.smoothcache_schedule(self.alpha, &ctx.family.branch_types);
        CachePlan::from_grouped(&s, &ctx.family.branch_sites())
    }
}

/// `smooth-persite:ALPHA`: independent per-site α-threshold decisions
/// (the grouping ablation).
struct SmoothPerSitePlanner {
    alpha: f64,
}

impl Planner for SmoothPerSitePlanner {
    fn wire(&self) -> String {
        format!("smooth-persite:{}", self.alpha)
    }

    fn needs_curves(&self) -> bool {
        true
    }

    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan> {
        let curves = ctx.curves.ok_or_else(|| {
            crate::err!("smooth-persite:{} needs calibrated curves", self.alpha)
        })?;
        let map = curves.per_site_schedule(self.alpha);
        CachePlan::from_site_map(
            &format!("smoothcache-persite-a{}", self.alpha),
            ctx.steps,
            &ctx.family.branch_sites(),
            &map,
        )
    }
}

/// `delta-dit:N`: depth-aware baseline (phase-dependent half of the
/// block stack cached, refresh interval N).
struct DeltaDitPlanner {
    n: usize,
}

impl Planner for DeltaDitPlanner {
    fn wire(&self) -> String {
        format!("delta-dit:{}", self.n)
    }

    fn plan(&self, ctx: &PlanCtx) -> Result<CachePlan> {
        let map = super::policies::delta_dit(
            ctx.steps,
            ctx.family.depth,
            &ctx.family.branch_types,
            self.n,
            0.5,
        );
        CachePlan::from_site_map(
            &format!("delta-dit-n{}", self.n),
            ctx.steps,
            &ctx.family.branch_sites(),
            &map,
        )
    }
}

/// `drift:BOUND[:GAP]` — the runtime-adaptive error-feedback policy: a
/// site keeps reusing its cached delta while the drift observed at its
/// most recent refresh stayed below `BOUND`, and falls back to
/// computing every step once the delta moves faster than that. Reuse
/// runs are additionally capped at `GAP` steps (default 3, the paper's
/// k_max) so stale deltas are refreshed — and each refresh measures
/// drift again, re-opening reuse when the trajectory calms down.
///
/// This is the CorGi/Δ-DiT-successor shape the static-only API could
/// not express: the decision depends on the *observed* trajectory, not
/// on offline calibration, so it needs no calibration pass at all.
pub struct DriftPlanner {
    /// relative L1 drift bound: reuse while the last observed
    /// per-refresh drift is ≤ this.
    pub bound: f64,
    /// maximum consecutive reuse steps per site.
    pub max_gap: usize,
}

impl Planner for DriftPlanner {
    fn wire(&self) -> String {
        if self.max_gap == DRIFT_DEFAULT_GAP {
            format!("drift:{}", self.bound)
        } else {
            format!("drift:{}:{}", self.bound, self.max_gap)
        }
    }

    fn plan(&self, _ctx: &PlanCtx) -> Result<CachePlan> {
        Err(crate::err!(
            "drift:{} is runtime-adaptive: it has no static plan (use Planner::dynamic)",
            self.bound
        ))
    }

    fn dynamic(&self) -> Option<&dyn StepPlanner> {
        Some(self)
    }
}

impl StepPlanner for DriftPlanner {
    fn name(&self) -> &str {
        "drift"
    }

    fn decide(&self, step: usize, _site: usize, obs: &StepObs) -> Decision {
        let Some(filled_at) = obs.filled_at else {
            return Decision::Compute; // cold cache
        };
        if step - filled_at > self.max_gap {
            return Decision::Compute; // cap staleness
        }
        match obs.last_drift {
            // error feedback: reuse only while the last refresh saw the
            // delta drifting slower than the bound
            Some(d) if d <= self.bound => Decision::Reuse { filled_at },
            _ => Decision::Compute,
        }
    }
}

const DRIFT_DEFAULT_GAP: usize = 3;

// ---------------------------------------------------------------------------
// Registry — the one policy table
// ---------------------------------------------------------------------------

/// Parser signature of one registry row: receives the text after
/// `name:` (or `None` when the wire string is the bare name).
pub type PolicyParseFn = fn(Option<&str>) -> Result<Arc<dyn Planner>>;

/// One row of the policy registry: wire name, syntax, lane hints, a
/// one-line description (rendered into docs/protocol.md — kept in sync
/// by a test), and the parser.
pub struct PolicySpec {
    /// wire-format name (the part before `:`).
    pub name: &'static str,
    /// full wire syntax, e.g. `fora:N`.
    pub syntax: &'static str,
    /// one-line human description (no `|` characters — it is rendered
    /// into a markdown table).
    pub summary: &'static str,
    /// true when resolving needs calibrated error curves (the policy
    /// may pay a cold calibration → work-queue normal lane until hot).
    pub needs_curves: bool,
    /// true when decisions are made at runtime by a [`StepPlanner`].
    pub dynamic: bool,
    /// parse the argument portion into a planner.
    pub parse: PolicyParseFn,
}

fn parse_bare(
    name: &'static str,
    arg: Option<&str>,
    mk: fn() -> Arc<dyn Planner>,
) -> Result<Arc<dyn Planner>> {
    match arg {
        None => Ok(mk()),
        Some(a) => Err(crate::err!("policy {name} takes no argument, got {a:?}")),
    }
}

/// Parse an α argument: finite and ≥ 0 (rejects `NaN`, `inf`, negatives).
fn parse_alpha(name: &str, arg: Option<&str>) -> Result<f64> {
    let a = arg.ok_or_else(|| crate::err!("{name} needs an alpha, e.g. {name}:0.35"))?;
    let v: f64 = a.parse().map_err(|_| crate::err!("bad {name} alpha {a:?}"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(crate::err!("{name} alpha must be finite and >= 0, got {a:?}"));
    }
    Ok(v)
}

/// Parse a refresh-interval argument: an integer ≥ 1 (rejects 0 — a
/// zero interval used to panic an executor replica from wire input).
fn parse_interval(name: &str, arg: Option<&str>) -> Result<usize> {
    let a = arg.ok_or_else(|| crate::err!("{name} needs an interval, e.g. {name}:2"))?;
    let v: usize = a.parse().map_err(|_| crate::err!("bad {name} interval {a:?}"))?;
    if v < 1 {
        return Err(crate::err!("{name} interval must be >= 1, got {a:?}"));
    }
    Ok(v)
}

static REGISTRY: [PolicySpec; 7] = [
    PolicySpec {
        name: "no-cache",
        syntax: "no-cache",
        summary: "every branch computes at every step (baseline rows; calibration)",
        needs_curves: false,
        dynamic: false,
        parse: |arg| parse_bare("no-cache", arg, || Arc::new(NoCachePlanner)),
    },
    PolicySpec {
        name: "fora",
        syntax: "fora:N",
        summary: "compute on every N-th step, reuse otherwise (FORA baseline)",
        needs_curves: false,
        dynamic: false,
        parse: |arg| Ok(Arc::new(ForaPlanner { n: parse_interval("fora", arg)? })),
    },
    PolicySpec {
        name: "alternate",
        syntax: "alternate",
        summary: "cache every other step (L2C-proxy baseline)",
        needs_curves: false,
        dynamic: false,
        parse: |arg| parse_bare("alternate", arg, || Arc::new(AlternatePlanner)),
    },
    PolicySpec {
        name: "smooth",
        syntax: "smooth:ALPHA",
        summary: "SmoothCache grouped schedule thresholded at ALPHA (paper Eq. 4)",
        needs_curves: true,
        dynamic: false,
        parse: |arg| Ok(Arc::new(SmoothPlanner { alpha: parse_alpha("smooth", arg)? })),
    },
    PolicySpec {
        name: "smooth-persite",
        syntax: "smooth-persite:ALPHA",
        summary: "SmoothCache with independent per-site decisions (grouping ablation)",
        needs_curves: true,
        dynamic: false,
        parse: |arg| {
            Ok(Arc::new(SmoothPerSitePlanner { alpha: parse_alpha("smooth-persite", arg)? }))
        },
    },
    PolicySpec {
        name: "delta-dit",
        syntax: "delta-dit:N",
        summary: "depth-aware baseline: the phase-dependent half of the block stack reuses with refresh interval N",
        needs_curves: false,
        dynamic: false,
        parse: |arg| Ok(Arc::new(DeltaDitPlanner { n: parse_interval("delta-dit", arg)? })),
    },
    PolicySpec {
        name: "drift",
        syntax: "drift:BOUND[:GAP]",
        summary: "runtime-adaptive error feedback: reuse while the observed cached-delta drift stays below BOUND, recompute otherwise (reuse runs capped at GAP steps, default 3)",
        needs_curves: false,
        dynamic: true,
        parse: parse_drift,
    },
];

fn parse_drift(arg: Option<&str>) -> Result<Arc<dyn Planner>> {
    let a = arg.ok_or_else(|| crate::err!("drift needs a bound, e.g. drift:0.35"))?;
    let (bound_s, gap_s) = match a.split_once(':') {
        Some((b, g)) => (b, Some(g)),
        None => (a, None),
    };
    let bound: f64 =
        bound_s.parse().map_err(|_| crate::err!("bad drift bound {bound_s:?}"))?;
    if !bound.is_finite() || bound <= 0.0 {
        return Err(crate::err!("drift bound must be finite and > 0, got {bound_s:?}"));
    }
    let max_gap = match gap_s {
        None => DRIFT_DEFAULT_GAP,
        Some(g) => {
            let v: usize = g.parse().map_err(|_| crate::err!("bad drift gap {g:?}"))?;
            if v < 1 {
                return Err(crate::err!("drift gap must be >= 1, got {g:?}"));
            }
            v
        }
    };
    Ok(Arc::new(DriftPlanner { bound, max_gap }))
}

/// The policy registry: every caching policy the stack understands, in
/// wire-documentation order. The CLI help text, the server's wire
/// format, `coordinator`'s lane choice and docs/protocol.md's policy
/// table are all derived from this one table.
pub fn registry() -> &'static [PolicySpec] {
    &REGISTRY
}

/// Parse a wire-format policy string (`no-cache`, `fora:2`,
/// `smooth:0.35`, `drift:0.3`, …) through the registry. Parameters are
/// validated here — malformed wire input (zero intervals, non-finite
/// alphas) returns a well-formed error instead of panicking later.
pub fn parse_policy(s: &str) -> Result<Arc<dyn Planner>> {
    let (name, arg) = match s.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (s, None),
    };
    for spec in registry() {
        if spec.name == name {
            return (spec.parse)(arg);
        }
    }
    let known: Vec<&str> = registry().iter().map(|p| p.name).collect();
    Err(crate::err!("unknown policy {s:?} (known: {known:?})"))
}

/// The registry rendered as markdown table rows (one per policy) —
/// docs/protocol.md embeds exactly these rows, and a test asserts it,
/// so the wire docs can no longer drift from the parser.
pub fn registry_markdown_rows() -> Vec<String> {
    registry()
        .iter()
        .map(|s| {
            let kind = if s.dynamic {
                "dynamic (runtime-decided)"
            } else if s.needs_curves {
                "static, needs calibration"
            } else {
                "static, calibration-free"
            };
            format!("| `{}` | `{}` | {} | {} |", s.name, s.syntax, kind, s.summary)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gen};

    fn sites2() -> Vec<(usize, String)> {
        vec![
            (0, "attn".into()),
            (0, "ffn".into()),
            (1, "attn".into()),
            (1, "ffn".into()),
        ]
    }

    #[test]
    fn no_cache_plan_is_all_compute() {
        let p = CachePlan::no_cache(5, &sites2());
        p.validate().unwrap();
        assert_eq!(p.skip_fraction(), 0.0);
        assert_eq!(p.computes_total(), 20);
        assert_eq!(p.max_gap(), 0);
    }

    #[test]
    fn from_grouped_expands_branch_types_over_sites() {
        let bts = vec!["attn".to_string(), "ffn".to_string()];
        let s = Schedule::fora(6, &bts, 2);
        let p = CachePlan::from_grouped(&s, &sites2()).unwrap();
        p.validate().unwrap();
        for step in 0..6 {
            for (site, (_, bt)) in sites2().iter().enumerate() {
                assert_eq!(p.decision(step, site), s.decision(step, bt), "step {step} site {site}");
            }
        }
        assert!((p.skip_fraction() - s.skip_fraction()).abs() < 1e-12);
        assert_eq!(p.max_gap(), s.max_gap());
    }

    #[test]
    fn from_grouped_rejects_missing_branch_type() {
        let s = Schedule::fora(4, &["attn".to_string()], 2);
        assert!(CachePlan::from_grouped(&s, &sites2()).is_err());
    }

    #[test]
    fn from_site_map_roundtrips_and_rejects_mismatches() {
        let mut map = BTreeMap::new();
        for (b, t) in sites2() {
            map.insert(format!("{b}.{t}"), vec![Decision::Compute; 4]);
        }
        let p = CachePlan::from_site_map("t", 4, &sites2(), &map).unwrap();
        assert_eq!(p.skip_fraction(), 0.0);

        // missing site → loud
        let mut missing = map.clone();
        missing.remove("1.ffn");
        let err = CachePlan::from_site_map("t", 4, &sites2(), &missing).unwrap_err();
        assert!(format!("{err}").contains("mismatch"), "{err}");

        // extra site → loud
        let mut extra = map.clone();
        extra.insert("9.ffn".into(), vec![Decision::Compute; 4]);
        assert!(CachePlan::from_site_map("t", 4, &sites2(), &extra).is_err());

        // wrong step count → loud
        let mut short = map.clone();
        short.insert("0.attn".into(), vec![Decision::Compute; 3]);
        assert!(CachePlan::from_site_map("t", 4, &sites2(), &short).is_err());
    }

    #[test]
    fn validate_rejects_broken_invariants() {
        let n = sites2().len();
        let mk = |f: &dyn Fn(&mut Vec<Decision>)| {
            let mut d = vec![Decision::Compute; 4 * n];
            f(&mut d);
            CachePlan::from_decisions("t", 4, sites2(), d)
        };
        assert!(mk(&|_| {}).validate().is_ok());
        // step-0 reuse
        assert!(mk(&|d| d[0] = Decision::Reuse { filled_at: 0 }).validate().is_err());
        // future fill
        assert!(mk(&|d| d[n] = Decision::Reuse { filled_at: 2 }).validate().is_err());
        // fill was not computed
        assert!(mk(&|d| {
            d[n] = Decision::Reuse { filled_at: 0 };
            d[2 * n] = Decision::Reuse { filled_at: 1 };
        })
        .validate()
        .is_err());
        // stale reuse (a newer compute exists between fill and step)
        assert!(mk(&|d| d[3 * n] = Decision::Reuse { filled_at: 1 }).validate().is_err());
        // wrong matrix size
        assert!(CachePlan::from_decisions("t", 4, sites2(), vec![Decision::Compute; 7])
            .validate()
            .is_err());
    }

    /// validate() accepts *exactly* the invariant-respecting plans: an
    /// independent oracle over random (mostly invalid) matrices agrees
    /// with it on every case.
    #[test]
    fn prop_validate_matches_independent_oracle() {
        fn oracle(steps: usize, n: usize, d: &[Decision]) -> bool {
            if d.len() != steps * n {
                return false;
            }
            for site in 0..n {
                for step in 0..steps {
                    if let Decision::Reuse { filled_at } = d[step * n + site] {
                        if step == 0 || filled_at >= step {
                            return false;
                        }
                        if !d[filled_at * n + site].is_compute() {
                            return false;
                        }
                        if ((filled_at + 1)..step).any(|m| d[m * n + site].is_compute()) {
                            return false;
                        }
                    }
                }
            }
            true
        }
        forall(
            0x9A11,
            400,
            |r| {
                let steps = gen::usize_in(r, 1, 8);
                let n = gen::usize_in(r, 1, 4);
                let cells = gen::vec_of(r, steps * n, steps * n + 1, |r| r.below(steps + 1));
                (steps, n, cells)
            },
            |&(steps, n, ref cells): &(usize, usize, Vec<usize>)| {
                let mut cells = cells.clone();
                cells.resize(steps * n, 0);
                let decisions: Vec<Decision> = cells
                    .iter()
                    .map(|&c| {
                        if c == 0 {
                            Decision::Compute
                        } else {
                            Decision::Reuse { filled_at: c - 1 }
                        }
                    })
                    .collect();
                let sites: Vec<(usize, String)> =
                    (0..n).map(|i| (i, "bt".to_string())).collect();
                let want = oracle(steps, n, &decisions);
                let plan = CachePlan::from_decisions("p", steps, sites, decisions);
                let got = plan.validate().is_ok();
                if got != want {
                    return Err(format!("validate={got} oracle={want}"));
                }
                Ok(())
            },
        );
    }

    /// Random *valid-by-construction* plans always pass validate().
    #[test]
    fn prop_constructed_plans_always_valid() {
        forall(
            0x9A12,
            200,
            |r| {
                let steps = gen::usize_in(r, 1, 12);
                let n = gen::usize_in(r, 1, 4);
                let cells = gen::vec_of(r, steps * n, steps * n + 1, |r| r.below(3));
                (steps, n, cells)
            },
            |&(steps, n, ref cells): &(usize, usize, Vec<usize>)| {
                let mut cells = cells.clone();
                cells.resize(steps * n, 0);
                // walk each site column keeping a last-fill pointer, so
                // every reuse is structurally legal by construction
                let mut decisions = vec![Decision::Compute; steps * n];
                for site in 0..n {
                    let mut last_fill = 0usize;
                    for step in 1..steps {
                        if cells[step * n + site] > 0 {
                            decisions[step * n + site] =
                                Decision::Reuse { filled_at: last_fill };
                        } else {
                            last_fill = step;
                        }
                    }
                }
                let sites: Vec<(usize, String)> =
                    (0..n).map(|i| (i, "bt".to_string())).collect();
                CachePlan::from_decisions("p", steps, sites, decisions)
                    .validate()
                    .map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn parse_rejects_malformed_parameters() {
        // zero intervals used to panic an executor via Schedule::fora's assert
        assert!(parse_policy("fora:0").is_err());
        assert!(parse_policy("delta-dit:0").is_err());
        // non-finite / negative alphas parse as f64 but are rejected here
        assert!(parse_policy("smooth:NaN").is_err());
        assert!(parse_policy("smooth:inf").is_err());
        assert!(parse_policy("smooth:-0.5").is_err());
        assert!(parse_policy("smooth-persite:nan").is_err());
        // drift: bound must be finite and positive, gap >= 1
        assert!(parse_policy("drift:0").is_err());
        assert!(parse_policy("drift:NaN").is_err());
        assert!(parse_policy("drift:0.3:0").is_err());
        // missing / extra arguments
        assert!(parse_policy("fora").is_err());
        assert!(parse_policy("smooth").is_err());
        assert!(parse_policy("no-cache:1").is_err());
        assert!(parse_policy("alternate:2").is_err());
        assert!(parse_policy("bogus").is_err());
    }

    #[test]
    fn parse_roundtrips_canonical_wire() {
        for wire in [
            "no-cache",
            "fora:2",
            "alternate",
            "smooth:0.18",
            "smooth-persite:0.05",
            "delta-dit:3",
            "drift:0.3",
            "drift:0.3:5",
        ] {
            let p = parse_policy(wire).unwrap();
            assert_eq!(p.wire(), wire);
            // re-parse of the canonical form is stable
            assert_eq!(parse_policy(&p.wire()).unwrap().wire(), wire);
        }
        // default gap is elided from the canonical form
        assert_eq!(parse_policy("drift:0.3:3").unwrap().wire(), "drift:0.3");
    }

    #[test]
    fn drift_planner_implements_error_feedback() {
        let p = DriftPlanner { bound: 0.5, max_gap: 3 };
        let cold = StepObs { filled_at: None, last_drift: None };
        assert!(p.decide(0, 0, &cold).is_compute());
        // filled but drift unknown yet → compute (records the first drift)
        let unknown = StepObs { filled_at: Some(0), last_drift: None };
        assert!(p.decide(1, 0, &unknown).is_compute());
        // calm delta → reuse
        let calm = StepObs { filled_at: Some(1), last_drift: Some(0.1) };
        assert_eq!(p.decide(2, 0, &calm), Decision::Reuse { filled_at: 1 });
        // gap cap: filled at 1, step 5 would be gap 4 > 3
        let stale = StepObs { filled_at: Some(1), last_drift: Some(0.1) };
        assert!(p.decide(5, 0, &stale).is_compute());
        // drifting delta → fall back to compute
        let hot = StepObs { filled_at: Some(4), last_drift: Some(0.9) };
        assert!(p.decide(5, 0, &hot).is_compute());
    }

    #[test]
    fn registry_rows_cover_every_policy() {
        let rows = registry_markdown_rows();
        assert_eq!(rows.len(), registry().len());
        for (row, spec) in rows.iter().zip(registry()) {
            assert!(row.contains(spec.name));
            assert!(!spec.summary.contains('|'), "{}: markdown-breaking summary", spec.name);
        }
    }
}
