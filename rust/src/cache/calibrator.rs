//! The calibration pass (paper §2.2): run a handful of no-cache
//! trajectories, record every branch output, and accumulate the
//! cross-timestep L1 relative error curves the schedule generator
//! consumes. One pass per (family, solver, steps) configuration — the
//! paper's "single calibration inference pass".

use std::collections::HashMap;

use crate::util::error::Result;

use super::curves::ErrorCurves;
use super::plan::{CachePlan, PlanRef};
use crate::model::{Cond, Engine};
use crate::pipeline::{generate, GenConfig};
use crate::solvers::SolverKind;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Settings for one calibration pass over a (family, solver, steps)
/// configuration.
#[derive(Clone, Debug)]
pub struct CalibrationConfig {
    /// solver whose trajectory the errors are measured along.
    pub solver: SolverKind,
    /// sampling steps of the calibrated configuration.
    pub steps: usize,
    /// maximum reuse gap considered (paper: 3 for DiT/StableAudio, 5 for
    /// OpenSora).
    pub k_max: usize,
    /// number of calibration samples (paper: 10 for all models).
    pub num_samples: usize,
    /// CFG scale during calibration (1.0 = unconditional, the DiT
    /// protocol; >1 = conditional, the OpenSora/StableAudio protocol).
    pub cfg_scale: f32,
    /// seed for conditioning draws and initial latents.
    pub seed: u64,
}

impl CalibrationConfig {
    /// Paper defaults (k_max 3, 10 samples, unconditional) for a
    /// (solver, steps) pair.
    pub fn new(solver: SolverKind, steps: usize) -> CalibrationConfig {
        CalibrationConfig { solver, steps, k_max: 3, num_samples: 10, cfg_scale: 1.0, seed: 7 }
    }
}

/// Default per-family calibration protocols, mirroring the paper's
/// experiment setup (§3.1): DiT-XL → DDIM-50 uncond k≤3; Stable Audio →
/// DPM++(3M)-SDE-100 cond k≤3; OpenSora → RF-30 cond k≤5.
pub fn paper_protocol(family: &str) -> CalibrationConfig {
    match family {
        "image" => CalibrationConfig::new(SolverKind::Ddim, 50),
        "audio" => CalibrationConfig {
            cfg_scale: 7.0,
            ..CalibrationConfig::new(SolverKind::DpmPP3M { sde: true }, 100)
        },
        "video" => CalibrationConfig {
            k_max: 5,
            cfg_scale: 7.0,
            ..CalibrationConfig::new(SolverKind::RectifiedFlow, 30)
        },
        other => panic!("unknown family {other}"),
    }
}

/// Sample a random conditioning input for calibration (labels for the
/// image family, prompt token ids otherwise). batch = 1.
pub fn sample_cond(
    rng: &mut Rng,
    num_classes: usize,
    vocab: usize,
    cond_len: usize,
    unconditional: bool,
) -> Cond {
    if num_classes > 0 {
        if unconditional {
            Cond::Label(vec![num_classes as i32])
        } else {
            Cond::Label(vec![rng.below(num_classes) as i32])
        }
    } else if unconditional {
        Cond::Prompt(vec![0; cond_len])
    } else {
        Cond::Prompt((0..cond_len).map(|_| rng.range(1, vocab) as i32).collect())
    }
}

/// Run the calibration pass and return the accumulated error curves.
pub fn calibrate(
    engine: &Engine,
    family: &str,
    cc: &CalibrationConfig,
) -> Result<ErrorCurves> {
    let fm = engine.family_manifest(family)?.clone();
    let mut curves = ErrorCurves::new(
        family,
        cc.solver.name(),
        cc.steps,
        cc.k_max,
        &fm.branch_types,
        fm.depth,
    );
    let mut rng = Rng::new(cc.seed);
    // calibration runs the no-cache trajectory (every branch computes)
    let no_cache = CachePlan::no_cache(cc.steps, &fm.branch_sites());

    for sample in 0..cc.num_samples {
        // DiT protocol: calibrate unconditionally (null label) when CFG is
        // off; otherwise condition on random prompts/labels (OpenSora /
        // Stable Audio protocol).
        let uncond = cc.cfg_scale <= 1.0;
        let cond = sample_cond(&mut rng, fm.num_classes, fm.vocab, fm.cond_len, uncond);
        let gen_cfg = GenConfig::new(family, cc.solver, cc.steps)
            .with_cfg(cc.cfg_scale)
            .with_seed(cc.seed ^ (sample as u64).wrapping_mul(0x9E3779B97F4A7C15));

        // Rolling per-site window of the last k_max deltas.
        let mut window: HashMap<(usize, String), Vec<(usize, Tensor)>> = HashMap::new();
        {
            let mut observer = |step: usize, block: usize, br: &str, delta: &Tensor| {
                let key = (block, br.to_string());
                let entry = window.entry(key).or_default();
                for (past_step, past) in entry.iter() {
                    let k = step - past_step;
                    if k >= 1 && k <= cc.k_max {
                        curves.record(br, block, step, k, delta.rel_l1_error(past));
                    }
                }
                entry.push((step, delta.clone()));
                let keep_from = step.saturating_sub(cc.k_max);
                entry.retain(|(s, _)| *s >= keep_from);
            };
            generate(engine, &gen_cfg, &cond, PlanRef::Plan(&no_cache), Some(&mut observer))?;
        }
        curves.num_samples += 1;
    }
    Ok(curves)
}
