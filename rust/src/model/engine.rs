//! Forward composition engine: executes a DiT forward pass one branch
//! at a time through a pluggable [`Backend`].
//!
//! This is the piece that makes SmoothCache *real* in this stack: the
//! denoising pipeline asks for one branch delta at a time
//! (`x <- x + delta`), so replacing a branch execution with a cached
//! tensor skips an actual backend execution (paper Fig. 3). The engine
//! resolves families from the manifest (on-disk artifacts, or the
//! builtin geometry when none exist), loads weights (from weights.bin,
//! or deterministic synthesis), and delegates the math to the backend
//! selected by [`crate::runtime::select_backend`].
//!
//! Backend handles may be thread-bound (PJRT); the coordinator talks to
//! each engine from exactly one executor thread (replicable backends
//! get one engine per executor in the worker pool).

use std::collections::HashMap;

use super::manifest::{FamilyManifest, Manifest};
use super::weights::WeightStore;
use super::Cond;
use crate::runtime::{reference, Backend, RuntimeStats};
pub use crate::runtime::{EmbedOut, StepCtx};
use crate::tensor::Tensor;
use crate::util::error::{Context, Result};

/// Seed for deterministic weight synthesis when no weights.bin artifact
/// exists (reference backend / offline quickstart).
const SYNTH_WEIGHT_SEED: u64 = 0x5EED_D17;

struct LoadedFamily {
    total_params: usize,
}

pub struct Engine {
    backend: Box<dyn Backend>,
    artifacts_dir: std::path::PathBuf,
    /// true when the manifest was read from disk — weight files are
    /// then required (a missing one means a broken artifact build).
    manifest_on_disk: bool,
    pub manifest: Manifest,
    families: HashMap<String, LoadedFamily>,
}

impl Engine {
    /// Open the artifacts directory (or fall back to the builtin
    /// manifest + reference backend when it holds none) and select the
    /// execution backend. Families are loaded on demand
    /// (`load_family`).
    pub fn open(dir: std::path::PathBuf) -> Result<Engine> {
        let (manifest, on_disk) = Manifest::load_or_builtin(&dir)?;
        let backend = crate::runtime::select_backend(&dir, on_disk)?;
        Ok(Engine {
            backend,
            artifacts_dir: dir,
            manifest_on_disk: on_disk,
            manifest,
            families: HashMap::new(),
        })
    }

    /// The active backend's identifier ("reference", "pjrt-cpu", …).
    pub fn platform(&self) -> String {
        self.backend.name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }

    pub fn reset_stats(&self) {
        self.backend.reset_stats()
    }

    pub fn family_manifest(&self, family: &str) -> Result<&FamilyManifest> {
        self.manifest.family(family)
    }

    pub fn is_loaded(&self, family: &str) -> bool {
        self.families.contains_key(family)
    }

    pub fn total_params(&self, family: &str) -> Option<usize> {
        self.families.get(family).map(|f| f.total_params)
    }

    /// Load a family: read weights.bin when the artifact exists,
    /// synthesize deterministic weights otherwise, and hand them to the
    /// backend (which uploads to its device where applicable).
    pub fn load_family(&mut self, family: &str) -> Result<()> {
        if self.families.contains_key(family) {
            return Ok(());
        }
        let fm = self.manifest.family(family)?.clone();
        let weights_path = self.artifacts_dir.join(&fm.weights_file);
        let weights = if weights_path.exists() {
            WeightStore::load(&weights_path)?
        } else if self.manifest_on_disk {
            // a real manifest promises its weight files; synthesizing
            // here would silently serve garbage from a broken build
            return Err(crate::err!(
                "artifacts manifest lists {:?} but the file is missing — run `make artifacts`",
                fm.weights_file
            ));
        } else {
            reference::synth_weights(&fm, SYNTH_WEIGHT_SEED)
        };
        let total_params = weights.total_params();
        self.backend
            .load_family(&fm, weights)
            .with_context(|| format!("loading family {family}"))?;
        self.families.insert(family.to_string(), LoadedFamily { total_params });
        Ok(())
    }

    /// Prepare every executable for the given batch size (avoids
    /// first-request latency on backends with a compile stage; used by
    /// the server warmup).
    pub fn warmup(&mut self, family: &str, batch: usize) -> Result<()> {
        self.load_family(family)?;
        let fm = self.manifest.family(family)?.clone();
        self.backend.warmup(&fm, batch)
    }

    fn loaded_manifest(&self, family: &str) -> Result<&FamilyManifest> {
        if !self.families.contains_key(family) {
            return Err(crate::err!("family {family:?} not loaded — call load_family"));
        }
        self.manifest.family(family)
    }

    /// Run the embed entry: latent + t + conditioning → (tokens, c, cond).
    pub fn embed(&self, family: &str, x: &Tensor, t: &[f32], cond: &Cond) -> Result<EmbedOut> {
        let fm = self.loaded_manifest(family)?;
        self.backend.embed(fm, x, t, cond)
    }

    /// Stage the per-step conditioning once (reused across all branches
    /// of the step).
    pub fn make_step_ctx(&self, embed: &EmbedOut) -> Result<StepCtx> {
        self.backend.make_step_ctx(embed)
    }

    /// Execute one branch: returns the gated pre-residual delta.
    pub fn branch(
        &self,
        family: &str,
        block: usize,
        branch: &str,
        tokens: &Tensor,
        ctx: &StepCtx,
    ) -> Result<Tensor> {
        let fm = self.loaded_manifest(family)?;
        self.backend.branch(fm, block, branch, tokens, ctx)
    }

    /// Execute the final head: tokens → epsilon prediction.
    pub fn final_head(&self, family: &str, tokens: &Tensor, ctx: &StepCtx) -> Result<Tensor> {
        let fm = self.loaded_manifest(family)?;
        self.backend.final_head(fm, tokens, ctx)
    }

    /// Full no-cache forward pass (calibration / golden tests). Returns
    /// the eps prediction and optionally records every branch delta via
    /// `on_delta(block, branch, &delta)`.
    pub fn forward(
        &self,
        family: &str,
        x: &Tensor,
        t: &[f32],
        cond: &Cond,
        mut on_delta: Option<&mut dyn FnMut(usize, &str, &Tensor)>,
    ) -> Result<Tensor> {
        let fm = self.loaded_manifest(family)?.clone();
        let emb = self.embed(family, x, t, cond)?;
        let ctx = self.make_step_ctx(&emb)?;
        let mut tokens = emb.tokens;
        for (block, br) in fm.branch_sites() {
            let delta = self.branch(family, block, &br, &tokens, &ctx)?;
            if let Some(cb) = on_delta.as_deref_mut() {
                cb(block, &br, &delta);
            }
            tokens.add_inplace(&delta);
        }
        self.final_head(family, &tokens, &ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Engine over a directory with no artifacts: builtin manifest +
    /// reference backend + synthesized weights.
    fn offline_engine() -> Engine {
        let mut e = Engine::open(std::path::PathBuf::from("/nonexistent-artifacts")).unwrap();
        e.load_family("image").unwrap();
        e
    }

    #[test]
    fn open_without_artifacts_uses_reference_backend() {
        let e = offline_engine();
        assert_eq!(e.platform(), "reference");
        assert!(e.is_loaded("image"));
        assert!(e.total_params("image").unwrap() > 100_000);
        assert!(!e.is_loaded("audio"));
    }

    #[test]
    fn forward_is_deterministic_and_latent_shaped() {
        let e = offline_engine();
        let mut rng = Rng::new(11);
        let x = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        let cond = Cond::Label(vec![3]);
        let a = e.forward("image", &x, &[0.5], &cond, None).unwrap();
        let b = e.forward("image", &x, &[0.5], &cond, None).unwrap();
        assert_eq!(a.shape, vec![1, 16, 16, 4]);
        assert_eq!(a, b);
    }

    #[test]
    fn forward_visits_every_branch_site() {
        let e = offline_engine();
        let mut rng = Rng::new(12);
        let x = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
        let mut sites = Vec::new();
        let mut cb = |block: usize, br: &str, _d: &Tensor| sites.push((block, br.to_string()));
        e.forward("image", &x, &[0.5], &Cond::Label(vec![0]), Some(&mut cb)).unwrap();
        let fm = e.family_manifest("image").unwrap();
        assert_eq!(sites, fm.branch_sites());
    }

    #[test]
    fn unloaded_family_errors() {
        let e = offline_engine();
        let mut rng = Rng::new(13);
        let x = Tensor::randn(vec![1, 64, 8], &mut rng);
        let err = e
            .embed("audio", &x, &[0.5], &Cond::Prompt(vec![1; 8]))
            .unwrap_err();
        assert!(err.to_string().contains("not loaded"), "{err}");
    }
}
