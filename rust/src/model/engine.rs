//! Forward composition engine: executes a DiT forward pass from
//! per-branch AOT executables, with residual adds on the host.
//!
//! This is the piece that makes SmoothCache *real* in this stack: the
//! denoising pipeline asks for one branch delta at a time
//! (`x <- x + delta`), so replacing a branch execution with a cached
//! tensor skips an actual PJRT execution (paper Fig. 3).
//!
//! The engine owns the PJRT runtime (not `Send`); the coordinator talks
//! to it from a single executor thread.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::manifest::{FamilyManifest, Manifest};
use super::weights::WeightStore;
use super::Cond;
use crate::runtime::{HostValue, Registry, Runtime};
use crate::tensor::Tensor;

/// Output of the embed entry for one (batch, t) invocation.
pub struct EmbedOut {
    pub tokens: Tensor,
    pub c: Tensor,
    pub cond: Option<Tensor>,
}

/// Device-resident per-step conditioning (c uploaded once per step, not
/// once per branch — the branch hot path uploads only the tokens).
pub struct StepCtx {
    pub batch: usize,
    c_buf: xla::PjRtBuffer,
    cond_buf: Option<xla::PjRtBuffer>,
}

struct LoadedFamily {
    manifest: FamilyManifest,
    #[allow(dead_code)]
    weights: WeightStore,
    /// resolved tensor name → device buffer (uploaded once at load).
    device_weights: HashMap<String, xla::PjRtBuffer>,
    total_params: usize,
}

pub struct Engine {
    pub rt: Runtime,
    pub registry: Registry,
    pub manifest: Manifest,
    families: HashMap<String, LoadedFamily>,
}

impl Engine {
    /// Open the artifacts directory and parse the manifest. Families are
    /// loaded on demand (`load_family`) or lazily on first use.
    pub fn open(dir: std::path::PathBuf) -> Result<Engine> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(&dir)?;
        Ok(Engine {
            rt,
            registry: Registry::new(dir),
            manifest,
            families: HashMap::new(),
        })
    }

    pub fn family_manifest(&self, family: &str) -> Result<&FamilyManifest> {
        self.manifest.family(family)
    }

    pub fn is_loaded(&self, family: &str) -> bool {
        self.families.contains_key(family)
    }

    pub fn total_params(&self, family: &str) -> Option<usize> {
        self.families.get(family).map(|f| f.total_params)
    }

    /// Load a family: read weights.bin and upload every tensor to the
    /// device once. Executables compile lazily per (entry, batch).
    pub fn load_family(&mut self, family: &str) -> Result<()> {
        if self.families.contains_key(family) {
            return Ok(());
        }
        let fm = self.manifest.family(family)?.clone();
        let weights = WeightStore::load(&self.registry.dir.join(&fm.weights_file))?;
        let mut device_weights = HashMap::new();
        for name in weights.names() {
            let t = weights.get(name)?;
            device_weights.insert(name.clone(), self.rt.upload(&HostValue::F32(t.clone()))?);
        }
        let total_params = weights.total_params();
        self.families.insert(
            family.to_string(),
            LoadedFamily { manifest: fm, weights, device_weights, total_params },
        );
        Ok(())
    }

    /// Pre-compile every executable for the given batch size (avoids
    /// first-request compile latency; used by the server warmup).
    pub fn warmup(&mut self, family: &str, batch: usize) -> Result<()> {
        self.load_family(family)?;
        let fm = self.families[family].manifest.clone();
        for (ename, entry) in &fm.entries {
            let file = entry
                .artifacts
                .get(&batch)
                .ok_or_else(|| anyhow!("{family}/{ename}: no batch-{batch} artifact"))?;
            self.registry.get(&self.rt, file, outputs_of(&fm, ename))?;
        }
        Ok(())
    }

    fn loaded(&self, family: &str) -> Result<&LoadedFamily> {
        self.families
            .get(family)
            .ok_or_else(|| anyhow!("family {family:?} not loaded — call load_family"))
    }

    fn weight_buffers<'a>(
        &'a self,
        lf: &'a LoadedFamily,
        templates: &[String],
        block: usize,
    ) -> Result<Vec<&'a xla::PjRtBuffer>> {
        templates
            .iter()
            .map(|tpl| {
                let name = tpl.replace("{i}", &block.to_string());
                lf.device_weights
                    .get(&name)
                    .ok_or_else(|| anyhow!("device weight {name:?} missing"))
            })
            .collect()
    }

    fn exec_entry(
        &self,
        family: &str,
        entry_name: &str,
        batch: usize,
        host_args: &[HostValue],
        extra_device: &[&xla::PjRtBuffer],
        block: usize,
    ) -> Result<Vec<Tensor>> {
        let lf = self.loaded(family)?;
        let entry = lf.manifest.entry(entry_name)?;
        let file = entry.artifacts.get(&batch).ok_or_else(|| {
            anyhow!(
                "{family}/{entry_name}: unsupported batch {batch} (have {:?})",
                entry.artifacts.keys().collect::<Vec<_>>()
            )
        })?;
        let exe = self
            .registry
            .get(&self.rt, file, outputs_of(&lf.manifest, entry_name))?;
        let wbufs = self.weight_buffers(lf, &entry.weights, block)?;
        let uploaded: Vec<xla::PjRtBuffer> =
            host_args.iter().map(|v| self.rt.upload(v)).collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = uploaded.iter().collect();
        args.extend_from_slice(extra_device);
        args.extend(wbufs);
        self.rt.execute(&exe, &args)
    }

    /// Run the embed entry: latent + t + conditioning → (tokens, c, cond).
    pub fn embed(&self, family: &str, x: &Tensor, t: &[f32], cond: &Cond) -> Result<EmbedOut> {
        let lf = self.loaded(family)?;
        let fm = &lf.manifest;
        let batch = x.dim0();
        assert_eq!(t.len(), batch, "t batch mismatch");
        let cond_val = match cond {
            Cond::Label(l) => {
                assert_eq!(l.len(), batch);
                HostValue::i32(vec![batch], l.clone())
            }
            Cond::Prompt(p) => {
                assert_eq!(p.len(), batch * fm.cond_len);
                HostValue::i32(vec![batch, fm.cond_len], p.clone())
            }
        };
        let host_args = vec![
            HostValue::F32(x.clone()),
            HostValue::F32(Tensor::new(vec![batch], t.to_vec())),
            cond_val,
        ];
        let mut out = self.exec_entry(family, "embed", batch, &host_args, &[], 0)?;
        let cond_t = if out.len() == 3 { Some(out.pop().unwrap()) } else { None };
        let c = out.pop().unwrap();
        let tokens = out.pop().unwrap();
        Ok(EmbedOut { tokens, c, cond: cond_t })
    }

    /// Upload the per-step conditioning once (reused across all branches
    /// of the step).
    pub fn make_step_ctx(&self, embed: &EmbedOut) -> Result<StepCtx> {
        Ok(StepCtx {
            batch: embed.tokens.dim0(),
            c_buf: self.rt.upload(&HostValue::F32(embed.c.clone()))?,
            cond_buf: match &embed.cond {
                Some(c) => Some(self.rt.upload(&HostValue::F32(c.clone()))?),
                None => None,
            },
        })
    }

    /// Execute one branch: returns the gated pre-residual delta.
    pub fn branch(
        &self,
        family: &str,
        block: usize,
        branch: &str,
        tokens: &Tensor,
        ctx: &StepCtx,
    ) -> Result<Tensor> {
        let lf = self.loaded(family)?;
        let entry_name = format!("branch.{branch}");
        let entry = lf.manifest.entry(&entry_name)?;
        let needs_cond = entry.inputs.iter().any(|i| i == "cond");
        let host_args = vec![HostValue::F32(tokens.clone())];
        let mut extra: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2);
        if needs_cond {
            extra.push(
                ctx.cond_buf
                    .as_ref()
                    .ok_or_else(|| anyhow!("{entry_name} needs cond tokens"))?,
            );
        }
        extra.push(&ctx.c_buf);
        let mut out =
            self.exec_entry(family, &entry_name, ctx.batch, &host_args, &extra, block)?;
        Ok(out.pop().unwrap())
    }

    /// Execute the final head: tokens → epsilon prediction.
    pub fn final_head(&self, family: &str, tokens: &Tensor, ctx: &StepCtx) -> Result<Tensor> {
        let host_args = vec![HostValue::F32(tokens.clone())];
        let mut out = self.exec_entry(
            family,
            "final",
            ctx.batch,
            &host_args,
            &[&ctx.c_buf],
            0,
        )?;
        Ok(out.pop().unwrap())
    }

    /// Full no-cache forward pass (calibration / golden tests). Returns
    /// the eps prediction and optionally records every branch delta via
    /// `on_delta(block, branch, &delta)`.
    pub fn forward(
        &self,
        family: &str,
        x: &Tensor,
        t: &[f32],
        cond: &Cond,
        mut on_delta: Option<&mut dyn FnMut(usize, &str, &Tensor)>,
    ) -> Result<Tensor> {
        let fm = self.loaded(family)?.manifest.clone();
        let emb = self.embed(family, x, t, cond)?;
        let ctx = self.make_step_ctx(&emb)?;
        let mut tokens = emb.tokens;
        for (block, br) in fm.branch_sites() {
            let delta = self.branch(family, block, &br, &tokens, &ctx)?;
            if let Some(cb) = on_delta.as_deref_mut() {
                cb(block, &br, &delta);
            }
            tokens.add_inplace(&delta);
        }
        self.final_head(family, &tokens, &ctx)
    }
}

/// Tuple arity of each entry's output.
fn outputs_of(fm: &FamilyManifest, entry: &str) -> usize {
    match entry {
        "embed" => {
            if fm.cond_len > 0 {
                3
            } else {
                2
            }
        }
        _ => 1,
    }
}
