//! weights.bin reader — the Rust half of the python weights_io contract.
//!
//! Format: `b"SMCWGT01"` magic, u32 LE header length, JSON header
//! `{"tensors": [{"name","shape","offset","count"}]}`, raw LE f32 data.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;

use crate::util::error::{Context, Result};

use crate::tensor::quant::{ComputeMode, QuantMat};
use crate::tensor::Tensor;
use crate::util::json::parse;

const MAGIC: &[u8; 8] = b"SMCWGT01";

#[derive(Debug, Default)]
pub struct WeightStore {
    tensors: BTreeMap<String, Tensor>,
    /// Lazily-built reduced-precision views of weight tensors, keyed by
    /// `(name, mode)` — quantizing is O(elements), so each weight is
    /// re-encoded at most once per mode and shared afterwards. RefCell
    /// is safe here: backends are single-threaded owners (see
    /// `runtime` module docs); GEMM pool workers only ever see the
    /// decoded slices captured by kernel closures, never the store.
    qcache: RefCell<HashMap<(String, ComputeMode), Arc<QuantMat>>>,
}

impl WeightStore {
    /// An empty store; backends fill it via [`WeightStore::insert`]
    /// (deterministic synthesis when no weights.bin artifact exists).
    pub fn new() -> WeightStore {
        WeightStore::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        // drop any stale quantized views of the replaced tensor
        self.qcache
            .borrow_mut()
            .retain(|(n, _), _| n != &name);
        self.tensors.insert(name, t);
    }

    pub fn load(path: &Path) -> Result<WeightStore> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse_bytes(&bytes)
    }

    pub fn parse_bytes(bytes: &[u8]) -> Result<WeightStore> {
        if bytes.len() < 12 || &bytes[..8] != MAGIC {
            return Err(crate::err!("bad weights magic"));
        }
        let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let header_end = 12 + hlen;
        if bytes.len() < header_end {
            return Err(crate::err!("truncated weights header"));
        }
        let header = std::str::from_utf8(&bytes[12..header_end])
            .map_err(|_| crate::err!("header not utf8"))?;
        let j = parse(header).map_err(|e| crate::err!("weights header: {e}"))?;
        let data = &bytes[header_end..];
        if data.len() % 4 != 0 {
            return Err(crate::err!("data section not f32-aligned"));
        }
        let floats: Vec<f32> = data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();

        let mut tensors = BTreeMap::new();
        for t in j
            .req("tensors")
            .map_err(|e| crate::err!("{e}"))?
            .as_arr()
            .ok_or_else(|| crate::err!("tensors not an array"))?
        {
            let name = t
                .req("name")
                .map_err(|e| crate::err!("{e}"))?
                .as_str()
                .ok_or_else(|| crate::err!("tensor name"))?
                .to_string();
            let shape = t
                .req("shape")
                .map_err(|e| crate::err!("{e}"))?
                .as_usize_vec()
                .ok_or_else(|| crate::err!("tensor shape"))?;
            let offset = t
                .req("offset")
                .map_err(|e| crate::err!("{e}"))?
                .as_usize()
                .ok_or_else(|| crate::err!("tensor offset"))?;
            let count = t
                .req("count")
                .map_err(|e| crate::err!("{e}"))?
                .as_usize()
                .ok_or_else(|| crate::err!("tensor count"))?;
            if offset + count > floats.len() {
                return Err(crate::err!("tensor {name}: out of bounds"));
            }
            if shape.iter().product::<usize>() != count {
                return Err(crate::err!("tensor {name}: shape/count mismatch"));
            }
            tensors.insert(name, Tensor::new(shape, floats[offset..offset + count].to_vec()));
        }
        Ok(WeightStore { tensors, ..WeightStore::default() })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| crate::err!("weight tensor {name:?} not found"))
    }

    /// The reduced-precision view of weight tensor `name`, quantizing
    /// and caching it on first use. The trailing shape dimension is the
    /// output width `n`; everything before it folds into `k`, matching
    /// how the reference backend feeds 2-D projection weights to
    /// [`crate::tensor::gemm::matmul`].
    pub fn get_quant(&self, name: &str, mode: ComputeMode) -> Result<Arc<QuantMat>> {
        if !mode.is_reduced() {
            return Err(crate::err!("get_quant: {} has no quantized form", mode.name()));
        }
        let key = (name.to_string(), mode);
        if let Some(q) = self.qcache.borrow().get(&key) {
            return Ok(Arc::clone(q));
        }
        let t = self.get(name)?;
        let n = *t
            .shape
            .last()
            .ok_or_else(|| crate::err!("weight tensor {name:?} is rank 0"))?;
        if n == 0 || t.data.is_empty() {
            return Err(crate::err!("weight tensor {name:?} is empty"));
        }
        let k = t.data.len() / n;
        let q = Arc::new(
            QuantMat::quantize(&t.data, k, n, mode).expect("reduced mode has a quantized form"),
        );
        self.qcache.borrow_mut().insert(key, Arc::clone(&q));
        Ok(q)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 2], "offset": 0, "count": 4},
            {"name": "b", "shape": [3], "offset": 4, "count": 3}
        ]}"#;
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header);
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parses_sample() {
        let w = WeightStore::parse_bytes(&sample_bytes()).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.get("a").unwrap().shape, vec![2, 2]);
        assert_eq!(w.get("a").unwrap().data, vec![1., 2., 3., 4.]);
        assert_eq!(w.get("b").unwrap().data, vec![5., 6., 7.]);
        assert_eq!(w.total_params(), 7);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_bytes();
        b[0] = b'X';
        assert!(WeightStore::parse_bytes(&b).is_err());
    }

    #[test]
    fn rejects_out_of_bounds() {
        let header = br#"{"tensors": [{"name": "a", "shape": [10], "offset": 0, "count": 10}]}"#;
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header);
        out.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(WeightStore::parse_bytes(&out).is_err());
    }

    #[test]
    fn missing_tensor_errors() {
        let w = WeightStore::parse_bytes(&sample_bytes()).unwrap();
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn get_quant_caches_and_insert_invalidates() {
        let mut w = WeightStore::parse_bytes(&sample_bytes()).unwrap();
        let q1 = w.get_quant("a", ComputeMode::F16).unwrap();
        let q2 = w.get_quant("a", ComputeMode::F16).unwrap();
        assert!(Arc::ptr_eq(&q1, &q2), "second lookup must hit the cache");
        assert_eq!(q1.dequantize(), vec![1.0, 2.0, 3.0, 4.0], "small ints are exact in f16");
        // replacing the tensor must drop the stale quantized view
        w.insert("a", Tensor::new(vec![2, 2], vec![8.0, 8.0, 8.0, 8.0]));
        let q3 = w.get_quant("a", ComputeMode::F16).unwrap();
        assert_eq!(q3.dequantize(), vec![8.0; 4]);
        // f32 has no quantized form; unknown tensors still error
        assert!(w.get_quant("a", ComputeMode::F32).is_err());
        assert!(w.get_quant("nope", ComputeMode::Int8).is_err());
    }
}
