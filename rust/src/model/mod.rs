//! Model layer: manifests, weights, and the forward composition engine.

pub mod engine;
pub mod manifest;
pub mod weights;

pub use engine::{EmbedOut, Engine, StepCtx};
pub use manifest::{EntryManifest, FamilyManifest, Manifest};
pub use weights::WeightStore;

/// Per-request conditioning input.
#[derive(Clone, Debug, PartialEq)]
pub enum Cond {
    /// Class label per sample (image family). The null class id
    /// (`num_classes`) is the CFG unconditional row.
    Label(Vec<i32>),
    /// Prompt token ids, `batch * cond_len` row-major (audio/video).
    /// Token id 0 is the CFG null token.
    Prompt(Vec<i32>),
}

impl Cond {
    pub fn batch(&self, cond_len: usize) -> usize {
        match self {
            Cond::Label(l) => l.len(),
            Cond::Prompt(p) => {
                assert!(cond_len > 0, "prompt cond on a family without cond tokens");
                p.len() / cond_len
            }
        }
    }

    /// The unconditional (null) counterpart with the same batch size.
    pub fn null_like(&self, num_classes: usize, cond_len: usize) -> Cond {
        match self {
            Cond::Label(l) => Cond::Label(vec![num_classes as i32; l.len()]),
            Cond::Prompt(p) => Cond::Prompt(vec![0; (p.len() / cond_len) * cond_len]),
        }
    }

    /// Concatenate along batch (CFG doubling).
    pub fn cat(&self, other: &Cond) -> Cond {
        match (self, other) {
            (Cond::Label(a), Cond::Label(b)) => {
                let mut v = a.clone();
                v.extend_from_slice(b);
                Cond::Label(v)
            }
            (Cond::Prompt(a), Cond::Prompt(b)) => {
                let mut v = a.clone();
                v.extend_from_slice(b);
                Cond::Prompt(v)
            }
            _ => panic!("mixing label and prompt conditioning"),
        }
    }

    /// Pad to batch `n` by repeating the last sample (batcher padding).
    pub fn pad_to(&self, n: usize, cond_len: usize) -> Cond {
        match self {
            Cond::Label(l) => {
                let mut v = l.clone();
                let last = *l.last().expect("non-empty");
                v.resize(n, last);
                Cond::Label(v)
            }
            Cond::Prompt(p) => {
                let b = p.len() / cond_len;
                let mut v = p.clone();
                let last = p[(b - 1) * cond_len..].to_vec();
                for _ in b..n {
                    v.extend_from_slice(&last);
                }
                Cond::Prompt(v)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_null_and_cat() {
        let c = Cond::Label(vec![1, 2]);
        assert_eq!(c.batch(0), 2);
        assert_eq!(c.null_like(10, 0), Cond::Label(vec![10, 10]));
        assert_eq!(
            c.cat(&c.null_like(10, 0)),
            Cond::Label(vec![1, 2, 10, 10])
        );
    }

    #[test]
    fn prompt_batch_and_pad() {
        let c = Cond::Prompt(vec![5, 6, 7, 8]); // batch 2, cond_len 2
        assert_eq!(c.batch(2), 2);
        let p = c.pad_to(4, 2);
        assert_eq!(p, Cond::Prompt(vec![5, 6, 7, 8, 7, 8, 7, 8]));
        assert_eq!(c.null_like(0, 2), Cond::Prompt(vec![0, 0, 0, 0]));
    }
}
