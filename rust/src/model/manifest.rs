//! manifest.json parsing — the contract written by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Clone, Debug)]
pub struct EntryManifest {
    /// Runtime input names, in argument order (before weights).
    pub inputs: Vec<String>,
    /// Weight tensor name templates (`blocks.{i}.attn.qkv_w` …), in order.
    pub weights: Vec<String>,
    /// batch size → artifact file name.
    pub artifacts: BTreeMap<usize, String>,
}

#[derive(Clone, Debug)]
pub struct FamilyManifest {
    pub name: String,
    pub hidden: usize,
    pub heads: usize,
    pub depth: usize,
    pub mlp_ratio: usize,
    pub seq_len: usize,
    pub latent_shape: Vec<usize>,
    pub branch_types: Vec<String>,
    pub cond_len: usize,
    pub num_classes: usize,
    pub vocab: usize,
    pub frames: usize,
    pub spatial_tokens: usize,
    pub patch: usize,
    pub t_freq_dim: usize,
    pub weights_file: String,
    pub impl_name: String,
    pub entries: BTreeMap<String, EntryManifest>,
}

impl FamilyManifest {
    pub fn latent_size(&self) -> usize {
        self.latent_shape.iter().product()
    }

    /// All (block, branch) pairs in execution order.
    pub fn branch_sites(&self) -> Vec<(usize, String)> {
        let mut out = Vec::with_capacity(self.depth * self.branch_types.len());
        for i in 0..self.depth {
            for b in &self.branch_types {
                out.push((i, b.clone()));
            }
        }
        out
    }

    pub fn entry(&self, name: &str) -> Result<&EntryManifest> {
        self.entries
            .get(name)
            .ok_or_else(|| crate::err!("family {}: no entry {name:?}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub impl_name: String,
    pub batch_sizes: Vec<usize>,
    pub families: BTreeMap<String, FamilyManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse_str(&text)
    }

    /// Load `manifest.json` when the artifacts directory has one,
    /// otherwise fall back to the [`Manifest::builtin`] geometry (the
    /// reference backend needs no on-disk artifacts). Returns the
    /// manifest and whether it came from disk. A *present but invalid*
    /// manifest is still an error — silent fallback would mask broken
    /// artifact builds.
    pub fn load_or_builtin(dir: &Path) -> Result<(Manifest, bool)> {
        if dir.join("manifest.json").exists() {
            Ok((Self::load(dir)?, true))
        } else {
            Ok((Self::builtin(), false))
        }
    }

    /// The three in-tree family geometries, mirroring
    /// `python/compile/families.py` (the single source of truth for the
    /// AOT path; this constructor is its Rust twin so the reference
    /// backend serves identical shapes with zero artifacts).
    pub fn builtin() -> Manifest {
        let mut families = BTreeMap::new();
        families.insert(
            "image".to_string(),
            builtin_family(BuiltinSpec {
                name: "image",
                depth: 6,
                latent_shape: vec![16, 16, 4],
                seq_len: 64,
                branch_types: &["attn", "ffn"],
                cond_len: 0,
                num_classes: 10,
                vocab: 0,
                frames: 0,
                spatial_tokens: 0,
            }),
        );
        families.insert(
            "audio".to_string(),
            builtin_family(BuiltinSpec {
                name: "audio",
                depth: 6,
                latent_shape: vec![64, 8],
                seq_len: 64,
                branch_types: &["attn", "xattn", "ffn"],
                cond_len: 8,
                num_classes: 0,
                vocab: 256,
                frames: 0,
                spatial_tokens: 0,
            }),
        );
        families.insert(
            "video".to_string(),
            builtin_family(BuiltinSpec {
                name: "video",
                depth: 4,
                latent_shape: vec![4, 8, 8, 4],
                seq_len: 64,
                branch_types: &["s_attn", "s_xattn", "s_ffn", "t_attn", "t_xattn", "t_ffn"],
                cond_len: 8,
                num_classes: 0,
                vocab: 256,
                frames: 4,
                spatial_tokens: 16,
            }),
        );
        Manifest {
            impl_name: "reference".to_string(),
            batch_sizes: vec![1, 2, 4, 8],
            families,
        }
    }

    pub fn parse_str(text: &str) -> Result<Manifest> {
        let j = parse(text).map_err(|e| crate::err!("manifest.json: {e}"))?;
        // a non-string impl used to silently default to "pallas",
        // mislabelling the artifact's provenance in `smoothcache info`
        // and every bench report that stamps it
        let impl_name = j
            .req("impl")?
            .as_str()
            .ok_or_else(|| crate::err!("manifest.json: impl must be a string"))?
            .to_string();
        let batch_sizes = j
            .req("batch_sizes")?
            .as_usize_vec()
            .ok_or_else(|| crate::err!("bad batch_sizes"))?;
        let mut families = BTreeMap::new();
        for (name, fj) in j
            .req("families")?
            .as_obj()
            .ok_or_else(|| crate::err!("families not an object"))?
        {
            families.insert(name.clone(), parse_family(name, fj)?);
        }
        Ok(Manifest { impl_name, batch_sizes, families })
    }

    pub fn family(&self, name: &str) -> Result<&FamilyManifest> {
        self.families
            .get(name)
            .ok_or_else(|| crate::err!("unknown family {name:?} (have: {:?})",
                self.families.keys().collect::<Vec<_>>()))
    }
}

/// Per-block weight parameter names for a branch type, in argument
/// order (mirrors `python/compile/families.py::branch_weight_names`).
/// Single source of truth for the builtin manifest entries and the
/// reference backend's weight synthesis / load validation.
pub fn branch_weight_names(branch: &str) -> &'static [&'static str] {
    if branch.ends_with("xattn") {
        &["mod_w", "mod_b", "q_w", "q_b", "kv_w", "kv_b", "o_w", "o_b"]
    } else if branch.ends_with("attn") {
        &["mod_w", "mod_b", "qkv_w", "qkv_b", "o_w", "o_b"]
    } else {
        &["mod_w", "mod_b", "w1", "b1", "w2", "b2"]
    }
}

/// Geometry knobs that differ between the builtin families (everything
/// else — hidden 128, 4 heads, mlp×4, patch 2, 64-dim t embedding — is
/// shared, as in python/compile/families.py).
struct BuiltinSpec {
    name: &'static str,
    depth: usize,
    latent_shape: Vec<usize>,
    seq_len: usize,
    branch_types: &'static [&'static str],
    cond_len: usize,
    num_classes: usize,
    vocab: usize,
    frames: usize,
    spatial_tokens: usize,
}

fn builtin_family(spec: BuiltinSpec) -> FamilyManifest {
    let branch_types: Vec<String> = spec.branch_types.iter().map(|s| s.to_string()).collect();
    let mut entries = BTreeMap::new();

    let embed_inputs: Vec<String> = if spec.num_classes > 0 {
        vec!["x".into(), "t".into(), "label".into()]
    } else {
        vec!["x".into(), "t".into(), "prompt_ids".into()]
    };
    let mut embed_weights: Vec<String> =
        ["patch_w", "patch_b", "pos", "temb_w1", "temb_b1", "temb_w2", "temb_b2"]
            .iter()
            .map(|n| format!("embed.{n}"))
            .collect();
    if spec.num_classes > 0 {
        embed_weights.push("embed.label_emb".into());
    }
    if spec.vocab > 0 {
        embed_weights.push("embed.prompt_emb".into());
    }
    entries.insert(
        "embed".to_string(),
        EntryManifest { inputs: embed_inputs, weights: embed_weights, artifacts: BTreeMap::new() },
    );

    for bt in &branch_types {
        let names = branch_weight_names(bt);
        let inputs: Vec<String> = if bt.ends_with("xattn") {
            vec!["x".into(), "cond".into(), "c".into()]
        } else {
            vec!["x".into(), "c".into()]
        };
        entries.insert(
            format!("branch.{bt}"),
            EntryManifest {
                inputs,
                weights: names.iter().map(|n| format!("blocks.{{i}}.{bt}.{n}")).collect(),
                artifacts: BTreeMap::new(),
            },
        );
    }

    entries.insert(
        "final".to_string(),
        EntryManifest {
            inputs: vec!["x".into(), "c".into()],
            weights: ["mod_w", "mod_b", "lin_w", "lin_b"]
                .iter()
                .map(|n| format!("final.{n}"))
                .collect(),
            artifacts: BTreeMap::new(),
        },
    );

    FamilyManifest {
        name: spec.name.to_string(),
        hidden: 128,
        heads: 4,
        depth: spec.depth,
        mlp_ratio: 4,
        seq_len: spec.seq_len,
        latent_shape: spec.latent_shape,
        branch_types,
        cond_len: spec.cond_len,
        num_classes: spec.num_classes,
        vocab: spec.vocab,
        frames: spec.frames,
        spatial_tokens: spec.spatial_tokens,
        patch: 2,
        t_freq_dim: 64,
        weights_file: format!("weights_{}.bin", spec.name),
        impl_name: "reference".to_string(),
        entries,
    }
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)
        .map_err(|e| crate::err!("{e}"))?
        .as_usize()
        .ok_or_else(|| crate::err!("{key}: not a number"))
}

fn parse_family(name: &str, j: &Json) -> Result<FamilyManifest> {
    let mut entries = BTreeMap::new();
    for (ename, ej) in j
        .req("entries")?
        .as_obj()
        .ok_or_else(|| crate::err!("entries not an object"))?
    {
        let inputs = ej
            .req("inputs")?
            .as_arr()
            .ok_or_else(|| crate::err!("inputs"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let weights = ej
            .req("weights")?
            .as_arr()
            .ok_or_else(|| crate::err!("weights"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut artifacts = BTreeMap::new();
        for (b, f) in ej
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| crate::err!("artifacts"))?
        {
            artifacts.insert(
                b.parse::<usize>().map_err(|_| crate::err!("bad batch key {b}"))?,
                f.as_str().ok_or_else(|| crate::err!("artifact name"))?.to_string(),
            );
        }
        entries.insert(ename.clone(), EntryManifest { inputs, weights, artifacts });
    }
    Ok(FamilyManifest {
        name: name.to_string(),
        hidden: get_usize(j, "hidden")?,
        heads: get_usize(j, "heads")?,
        depth: get_usize(j, "depth")?,
        mlp_ratio: get_usize(j, "mlp_ratio")?,
        seq_len: get_usize(j, "seq_len")?,
        latent_shape: j
            .req("latent_shape")?
            .as_usize_vec()
            .ok_or_else(|| crate::err!("latent_shape"))?,
        branch_types: j
            .req("branch_types")?
            .as_arr()
            .ok_or_else(|| crate::err!("branch_types"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect(),
        cond_len: get_usize(j, "cond_len")?,
        num_classes: get_usize(j, "num_classes")?,
        vocab: get_usize(j, "vocab")?,
        frames: get_usize(j, "frames")?,
        spatial_tokens: get_usize(j, "spatial_tokens")?,
        patch: get_usize(j, "patch")?,
        t_freq_dim: get_usize(j, "t_freq_dim")?,
        weights_file: j
            .req("weights_file")?
            .as_str()
            .ok_or_else(|| crate::err!("weights_file"))?
            .to_string(),
        impl_name: j
            .req("impl")?
            .as_str()
            .ok_or_else(|| crate::err!("impl"))?
            .to_string(),
        entries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "impl": "pallas", "batch_sizes": [1, 2],
      "families": {
        "image": {
          "hidden": 128, "heads": 4, "depth": 6, "mlp_ratio": 4,
          "seq_len": 64, "latent_shape": [16, 16, 4],
          "branch_types": ["attn", "ffn"],
          "cond_len": 0, "num_classes": 10, "vocab": 0,
          "frames": 0, "spatial_tokens": 0, "patch": 2, "t_freq_dim": 64,
          "weights_file": "weights_image.bin", "impl": "pallas",
          "entries": {
            "embed": {"inputs": ["x", "t", "label"],
                      "weights": ["embed.patch_w"],
                      "artifacts": {"1": "image_embed_b1.hlo.txt"}},
            "branch.attn": {"inputs": ["x", "c"],
                      "weights": ["blocks.{i}.attn.qkv_w"],
                      "artifacts": {"1": "image_branch_attn_b1.hlo.txt"}},
            "final": {"inputs": ["x", "c"], "weights": ["final.lin_w"],
                      "artifacts": {"1": "image_final_b1.hlo.txt"}}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.impl_name, "pallas");
        assert_eq!(m.batch_sizes, vec![1, 2]);
        let f = m.family("image").unwrap();
        assert_eq!(f.hidden, 128);
        assert_eq!(f.branch_types, vec!["attn", "ffn"]);
        assert_eq!(f.latent_size(), 16 * 16 * 4);
        assert_eq!(f.branch_sites().len(), 12);
        assert_eq!(
            f.entry("branch.attn").unwrap().artifacts.get(&1).unwrap(),
            "image_branch_attn_b1.hlo.txt"
        );
    }

    #[test]
    fn malformed_impl_is_a_typed_error_not_a_pallas_default() {
        // a numeric/array impl used to silently read as "pallas",
        // stamping wrong provenance into info output and bench reports
        for replacement in [r#""impl": 3"#, r#""impl": ["pallas"]"#, r#""impl": null"#] {
            let bad = SAMPLE.replacen(r#""impl": "pallas""#, replacement, 1);
            assert_ne!(bad, SAMPLE);
            let err = Manifest::parse_str(&bad).unwrap_err();
            assert!(format!("{err}").contains("impl"), "{replacement}: {err}");
        }
        // a missing impl field is an error too
        let missing = SAMPLE.replacen(r#""impl": "pallas","#, "", 1);
        assert!(Manifest::parse_str(&missing).is_err());
    }

    #[test]
    fn builtin_manifest_is_consistent() {
        let m = Manifest::builtin();
        for name in ["image", "audio", "video"] {
            let f = m.family(name).unwrap();
            assert_eq!(f.latent_size() % f.seq_len, 0, "{name}: non-integer patch dim");
            assert!(f.entries.contains_key("embed"));
            assert!(f.entries.contains_key("final"));
            for bt in &f.branch_types {
                let e = f.entry(&format!("branch.{bt}")).unwrap();
                let needs_cond = e.inputs.iter().any(|i| i == "cond");
                assert_eq!(needs_cond, bt.ends_with("xattn"), "{name}/{bt}");
            }
        }
        assert_eq!(m.family("image").unwrap().branch_sites().len(), 12);
        assert_eq!(m.family("video").unwrap().branch_sites().len(), 24);
    }

    #[test]
    fn unknown_family_errors() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert!(m.family("nope").is_err());
    }

    #[test]
    fn branch_sites_order_matches_execution() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        let f = m.family("image").unwrap();
        let sites = f.branch_sites();
        assert_eq!(sites[0], (0, "attn".to_string()));
        assert_eq!(sites[1], (0, "ffn".to_string()));
        assert_eq!(sites[2], (1, "attn".to_string()));
    }
}
