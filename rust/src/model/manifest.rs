//! manifest.json parsing — the contract written by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

#[derive(Clone, Debug)]
pub struct EntryManifest {
    /// Runtime input names, in argument order (before weights).
    pub inputs: Vec<String>,
    /// Weight tensor name templates (`blocks.{i}.attn.qkv_w` …), in order.
    pub weights: Vec<String>,
    /// batch size → artifact file name.
    pub artifacts: BTreeMap<usize, String>,
}

#[derive(Clone, Debug)]
pub struct FamilyManifest {
    pub name: String,
    pub hidden: usize,
    pub heads: usize,
    pub depth: usize,
    pub mlp_ratio: usize,
    pub seq_len: usize,
    pub latent_shape: Vec<usize>,
    pub branch_types: Vec<String>,
    pub cond_len: usize,
    pub num_classes: usize,
    pub vocab: usize,
    pub frames: usize,
    pub spatial_tokens: usize,
    pub patch: usize,
    pub t_freq_dim: usize,
    pub weights_file: String,
    pub impl_name: String,
    pub entries: BTreeMap<String, EntryManifest>,
}

impl FamilyManifest {
    pub fn latent_size(&self) -> usize {
        self.latent_shape.iter().product()
    }

    /// All (block, branch) pairs in execution order.
    pub fn branch_sites(&self) -> Vec<(usize, String)> {
        let mut out = Vec::with_capacity(self.depth * self.branch_types.len());
        for i in 0..self.depth {
            for b in &self.branch_types {
                out.push((i, b.clone()));
            }
        }
        out
    }

    pub fn entry(&self, name: &str) -> Result<&EntryManifest> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("family {}: no entry {name:?}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub impl_name: String,
    pub batch_sizes: Vec<usize>,
    pub families: BTreeMap<String, FamilyManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse_str(&text)
    }

    pub fn parse_str(text: &str) -> Result<Manifest> {
        let j = parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let impl_name = j.req("impl")?.as_str().unwrap_or("pallas").to_string();
        let batch_sizes = j
            .req("batch_sizes")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad batch_sizes"))?;
        let mut families = BTreeMap::new();
        for (name, fj) in j
            .req("families")?
            .as_obj()
            .ok_or_else(|| anyhow!("families not an object"))?
        {
            families.insert(name.clone(), parse_family(name, fj)?);
        }
        Ok(Manifest { impl_name, batch_sizes, families })
    }

    pub fn family(&self, name: &str) -> Result<&FamilyManifest> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow!("unknown family {name:?} (have: {:?})",
                self.families.keys().collect::<Vec<_>>()))
    }
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)
        .map_err(|e| anyhow!("{e}"))?
        .as_usize()
        .ok_or_else(|| anyhow!("{key}: not a number"))
}

fn parse_family(name: &str, j: &Json) -> Result<FamilyManifest> {
    let mut entries = BTreeMap::new();
    for (ename, ej) in j
        .req("entries")?
        .as_obj()
        .ok_or_else(|| anyhow!("entries not an object"))?
    {
        let inputs = ej
            .req("inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("inputs"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let weights = ej
            .req("weights")?
            .as_arr()
            .ok_or_else(|| anyhow!("weights"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut artifacts = BTreeMap::new();
        for (b, f) in ej
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts"))?
        {
            artifacts.insert(
                b.parse::<usize>().map_err(|_| anyhow!("bad batch key {b}"))?,
                f.as_str().ok_or_else(|| anyhow!("artifact name"))?.to_string(),
            );
        }
        entries.insert(ename.clone(), EntryManifest { inputs, weights, artifacts });
    }
    Ok(FamilyManifest {
        name: name.to_string(),
        hidden: get_usize(j, "hidden")?,
        heads: get_usize(j, "heads")?,
        depth: get_usize(j, "depth")?,
        mlp_ratio: get_usize(j, "mlp_ratio")?,
        seq_len: get_usize(j, "seq_len")?,
        latent_shape: j
            .req("latent_shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("latent_shape"))?,
        branch_types: j
            .req("branch_types")?
            .as_arr()
            .ok_or_else(|| anyhow!("branch_types"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect(),
        cond_len: get_usize(j, "cond_len")?,
        num_classes: get_usize(j, "num_classes")?,
        vocab: get_usize(j, "vocab")?,
        frames: get_usize(j, "frames")?,
        spatial_tokens: get_usize(j, "spatial_tokens")?,
        patch: get_usize(j, "patch")?,
        t_freq_dim: get_usize(j, "t_freq_dim")?,
        weights_file: j
            .req("weights_file")?
            .as_str()
            .ok_or_else(|| anyhow!("weights_file"))?
            .to_string(),
        impl_name: j
            .req("impl")?
            .as_str()
            .ok_or_else(|| anyhow!("impl"))?
            .to_string(),
        entries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "impl": "pallas", "batch_sizes": [1, 2],
      "families": {
        "image": {
          "hidden": 128, "heads": 4, "depth": 6, "mlp_ratio": 4,
          "seq_len": 64, "latent_shape": [16, 16, 4],
          "branch_types": ["attn", "ffn"],
          "cond_len": 0, "num_classes": 10, "vocab": 0,
          "frames": 0, "spatial_tokens": 0, "patch": 2, "t_freq_dim": 64,
          "weights_file": "weights_image.bin", "impl": "pallas",
          "entries": {
            "embed": {"inputs": ["x", "t", "label"],
                      "weights": ["embed.patch_w"],
                      "artifacts": {"1": "image_embed_b1.hlo.txt"}},
            "branch.attn": {"inputs": ["x", "c"],
                      "weights": ["blocks.{i}.attn.qkv_w"],
                      "artifacts": {"1": "image_branch_attn_b1.hlo.txt"}},
            "final": {"inputs": ["x", "c"], "weights": ["final.lin_w"],
                      "artifacts": {"1": "image_final_b1.hlo.txt"}}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.batch_sizes, vec![1, 2]);
        let f = m.family("image").unwrap();
        assert_eq!(f.hidden, 128);
        assert_eq!(f.branch_types, vec!["attn", "ffn"]);
        assert_eq!(f.latent_size(), 16 * 16 * 4);
        assert_eq!(f.branch_sites().len(), 12);
        assert_eq!(
            f.entry("branch.attn").unwrap().artifacts.get(&1).unwrap(),
            "image_branch_attn_b1.hlo.txt"
        );
    }

    #[test]
    fn unknown_family_errors() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert!(m.family("nope").is_err());
    }

    #[test]
    fn branch_sites_order_matches_execution() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        let f = m.family("image").unwrap();
        let sites = f.branch_sites();
        assert_eq!(sites[0], (0, "attn".to_string()));
        assert_eq!(sites[1], (0, "ffn".to_string()));
        assert_eq!(sites[2], (1, "attn".to_string()));
    }
}
