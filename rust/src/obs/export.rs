//! Timeline export: Chrome trace-event JSON and a human-readable
//! renderer over flight-recorder dumps (docs/adr/009).
//!
//! The wire shapes here are owned mirrors of the in-process types in
//! [`super`]: a `{"cmd":"dump"}` reply parses into [`DumpEntry`]s
//! (event names become owned strings — the in-process
//! [`TraceEvent`](super::TraceEvent) keeps `&'static str` names so
//! recording never allocates), and [`chrome_trace`] turns them into a
//! `chrome://tracing` / Perfetto-loadable trace-event document:
//! completed spans as `"ph":"X"` complete events, instants as
//! `"ph":"i"`, one `tid` row per trace. [`render`] is the
//! `smoothcache trace` CLI's plain-text timeline.

use crate::util::error::Result;
use crate::util::json::Json;

use super::FlightEntry;

/// Owned trace event parsed back from a dump (wire mirror of
/// [`TraceEvent`](super::TraceEvent)).
#[derive(Clone, Debug, PartialEq)]
pub struct DumpEvent {
    /// Event name.
    pub name: String,
    /// Microseconds since the trace started.
    pub t_us: u64,
    /// Span duration (0 = instant).
    pub dur_us: u64,
    /// Integer payloads (per-name meaning, docs/protocol.md).
    pub a: u64,
    /// Second integer payload.
    pub b: u64,
    /// Third integer payload.
    pub c: u64,
    /// Optional float payload.
    pub f: Option<f64>,
}

/// Owned flight-recorder entry parsed back from a dump (wire mirror of
/// [`FlightEntry`]).
#[derive(Clone, Debug)]
pub struct DumpEntry {
    /// Trace id.
    pub trace_id: u64,
    /// Coordinator request id (0 when never admitted).
    pub request_id: u64,
    /// Family / policy label.
    pub label: String,
    /// Terminal outcome label.
    pub outcome: String,
    /// True when retained in the pinned lane.
    pub pinned: bool,
    /// Events dropped past the per-trace cap.
    pub dropped: u64,
    /// The timeline.
    pub events: Vec<DumpEvent>,
}

impl DumpEvent {
    /// Parse one event object from a dump / timeline.
    pub fn from_json(j: &Json) -> Result<DumpEvent> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| crate::err!("trace event: missing name"))?
            .to_string();
        let num = |key: &str| j.get(key).and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(DumpEvent {
            name,
            t_us: num("t_us"),
            dur_us: num("dur_us"),
            a: num("a"),
            b: num("b"),
            c: num("c"),
            f: j.get("f").and_then(|v| v.as_f64()),
        })
    }
}

impl DumpEntry {
    /// Parse one flight entry object.
    pub fn from_json(j: &Json) -> Result<DumpEntry> {
        let events = j
            .get("events")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::err!("flight entry: missing events array"))?
            .iter()
            .map(DumpEvent::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(DumpEntry {
            trace_id: j.get("trace_id").and_then(|v| v.as_u64()).unwrap_or(0),
            request_id: j.get("request_id").and_then(|v| v.as_u64()).unwrap_or(0),
            label: j.get("label").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            outcome: j.get("outcome").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            pinned: j.get("pinned").and_then(|v| v.as_bool()).unwrap_or(false),
            dropped: j.get("dropped").and_then(|v| v.as_u64()).unwrap_or(0),
            events,
        })
    }

    /// Parse a whole `{"cmd":"dump"}` reply (or one `"trace"` response
    /// field wrapped as a single-entry dump) into entries.
    pub fn from_dump(j: &Json) -> Result<Vec<DumpEntry>> {
        let entries = j
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::err!("dump reply: missing entries array"))?;
        entries.iter().map(DumpEntry::from_json).collect()
    }
}

impl From<&FlightEntry> for DumpEntry {
    fn from(e: &FlightEntry) -> DumpEntry {
        DumpEntry {
            trace_id: e.trace_id,
            request_id: e.request_id,
            label: e.label.clone(),
            outcome: e.outcome.to_string(),
            pinned: e.pinned,
            dropped: e.dropped,
            events: e
                .events
                .iter()
                .map(|ev| DumpEvent {
                    name: ev.name.to_string(),
                    t_us: ev.t_us,
                    dur_us: ev.dur_us,
                    a: ev.a,
                    b: ev.b,
                    c: ev.c,
                    f: if ev.f.is_finite() { Some(ev.f) } else { None },
                })
                .collect(),
        }
    }
}

/// Give an event's generic `a`/`b`/`c`/`f` payloads their semantic
/// names (shared by the Chrome exporter and the text renderer).
fn args_json(ev: &DumpEvent) -> Json {
    let mut j = match ev.name.as_str() {
        "submit" => Json::obj().set("request_id", ev.a),
        "queue_push" => Json::obj().set("queue_depth", ev.a),
        "queue_pop" => Json::obj(),
        "batch" => Json::obj().set("members", ev.a).set("padded", ev.b),
        "step" => Json::obj().set("step", ev.a).set("computes", ev.b).set("reuses", ev.c),
        "site" => Json::obj().set("step", ev.a).set("site", ev.b).set(
            "decision",
            if ev.c == 1 { "compute" } else { "reuse" },
        ),
        "park" | "resume" => Json::obj().set("step", ev.a),
        "frame_in" | "frame_out" | "recv" | "send" => Json::obj().set("bytes", ev.a),
        "reject" | "calibrate" | "plan" => Json::obj(),
        _ => Json::obj().set("a", ev.a).set("b", ev.b).set("c", ev.c),
    };
    if let Some(f) = ev.f {
        let key = match ev.name.as_str() {
            "queue_pop" => "wait_s",
            "step" | "site" => "drift",
            "resume" => "parked_s",
            _ => "f",
        };
        j = j.set(key, f);
    }
    j
}

/// Build a Chrome trace-event document (the JSON-object form with a
/// `traceEvents` array) from dump entries. Spans become `"ph":"X"`
/// complete events and instants `"ph":"i"`; each trace gets its own
/// `tid` row under one `pid`, plus a thread-name metadata record
/// labelling the row with the trace id, outcome, and label.
pub fn chrome_trace(entries: &[DumpEntry]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for e in entries {
        events.push(
            Json::obj()
                .set("name", "thread_name")
                .set("ph", "M")
                .set("pid", 1u64)
                .set("tid", e.trace_id)
                .set(
                    "args",
                    Json::obj().set(
                        "name",
                        format!("trace {} [{}] {}", e.trace_id, e.outcome, e.label),
                    ),
                ),
        );
        for ev in &e.events {
            let mut j = Json::obj()
                .set("name", ev.name.as_str())
                .set("cat", "smoothcache")
                .set("ts", ev.t_us)
                .set("pid", 1u64)
                .set("tid", e.trace_id)
                .set("args", args_json(ev));
            if ev.dur_us > 0 {
                j = j.set("ph", "X").set("dur", ev.dur_us);
            } else {
                j = j.set("ph", "i").set("s", "t");
            }
            events.push(j);
        }
    }
    Json::obj().set("traceEvents", Json::Arr(events)).set("displayTimeUnit", "ms")
}

/// Render dump entries as a plain-text timeline (the `smoothcache
/// trace` default output).
pub fn render(entries: &[DumpEntry]) -> String {
    let mut out = String::new();
    for e in entries {
        out.push_str(&format!(
            "trace {} request {} [{}]{} {}{}\n",
            e.trace_id,
            e.request_id,
            e.outcome,
            if e.pinned { " pinned" } else { "" },
            e.label,
            if e.dropped > 0 { format!(" ({} events dropped)", e.dropped) } else { String::new() },
        ));
        let mut events = e.events.clone();
        events.sort_by_key(|ev| ev.t_us);
        for ev in &events {
            let dur = if ev.dur_us > 0 {
                format!(" +{:>7.3}ms", ev.dur_us as f64 / 1e3)
            } else {
                "           ".to_string()
            };
            out.push_str(&format!(
                "  {:>10.3}ms{dur}  {:<10} {}\n",
                ev.t_us as f64 / 1e3,
                ev.name,
                args_json(ev).to_string(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample() -> DumpEntry {
        DumpEntry {
            trace_id: 9,
            request_id: 3,
            label: "image/no-cache".into(),
            outcome: "ok".into(),
            pinned: false,
            dropped: 0,
            events: vec![
                DumpEvent { name: "submit".into(), t_us: 1, dur_us: 0, a: 3, b: 0, c: 0, f: None },
                DumpEvent {
                    name: "step".into(),
                    t_us: 10,
                    dur_us: 40,
                    a: 0,
                    b: 5,
                    c: 2,
                    f: Some(0.25),
                },
            ],
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let j = chrome_trace(&[sample()]);
        let back = parse(&j.to_string()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 metadata + 2 events
        assert_eq!(evs.len(), 3);
        let span = evs
            .iter()
            .find(|e| e.get("name").and_then(|v| v.as_str()) == Some("step"))
            .unwrap();
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("dur").unwrap().as_u64(), Some(40));
        assert_eq!(span.get("tid").unwrap().as_u64(), Some(9));
        let args = span.get("args").unwrap();
        assert_eq!(args.get("computes").unwrap().as_u64(), Some(5));
        assert_eq!(args.get("drift").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn dump_roundtrip() {
        let e = sample();
        let wire = Json::obj().set(
            "entries",
            Json::Arr(vec![Json::obj()
                .set("trace_id", e.trace_id)
                .set("request_id", e.request_id)
                .set("label", e.label.as_str())
                .set("outcome", e.outcome.as_str())
                .set("pinned", e.pinned)
                .set("dropped", e.dropped)
                .set(
                    "events",
                    Json::Arr(vec![
                        parse(r#"{"name":"submit","t_us":1,"dur_us":0,"a":3,"b":0,"c":0}"#)
                            .unwrap(),
                        parse(
                            r#"{"name":"step","t_us":10,"dur_us":40,"a":0,"b":5,"c":2,"f":0.25}"#,
                        )
                        .unwrap(),
                    ]),
                )]),
        );
        let parsed = DumpEntry::from_dump(&wire).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].trace_id, 9);
        assert_eq!(parsed[0].events, e.events);
        assert!(DumpEntry::from_dump(&Json::obj()).is_err());
    }

    #[test]
    fn render_mentions_every_event() {
        let text = render(&[sample()]);
        assert!(text.contains("trace 9"), "{text}");
        assert!(text.contains("submit"), "{text}");
        assert!(text.contains("step"), "{text}");
    }
}
