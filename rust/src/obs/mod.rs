//! `obs` — structured tracing, per-request timelines, and a flight
//! recorder across the serving stack (docs/adr/009-observability-subsystem.md).
//!
//! The serving pipeline (queue → batcher → executor → `GenSession` →
//! mux) exposes aggregate counters through
//! [`Metrics`](crate::coordinator::Metrics), but aggregates cannot
//! answer "where did *this* request's 180 ms go, and which sites did it
//! reuse at step 17?". This module adds exactly that, with the same
//! zero-dependency discipline as the rest of the crate (ADR-001):
//!
//! * **[`TraceHandle`]** — a cheap, cloneable per-request trace context
//!   (trace id + its own monotonic clock) attached to every submission.
//!   Instrumentation sites record instant events and completed spans
//!   into the handle's bounded buffer; when tracing is `off` the handle
//!   is a `None` and every operation is a branch on a machine word —
//!   no allocation, no lock, no clock read (pinned by
//!   `tests/obs.rs::disabled_mode_allocates_nothing`).
//! * **[`TraceLevel`]** — `off` / `coarse` / `fine`, selected by
//!   `SMOOTHCACHE_TRACE` at first use and overridable programmatically
//!   with [`set_level`]. The default is `coarse`, so the flight
//!   recorder is always populated in a normally-configured server.
//!   `fine` additionally records one event per (step, site) reuse
//!   decision via the thread-local staging buffer below.
//! * **[`FlightRecorder`]** — a process-wide ring that retains the
//!   complete timelines of the last N finished requests. Requests that
//!   errored, were cancelled, or missed their deadline are **pinned**
//!   into a separate bounded lane so they survive ring wraparound —
//!   the entries an operator actually wants are the ones a plain ring
//!   evicts first. `{"cmd":"dump"}` on the wire and `smoothcache trace`
//!   on the CLI read it out (docs/protocol.md).
//! * **fine-granularity staging** — per-site decisions are the hot
//!   path (sites × steps events per batch), so they stage in a
//!   per-thread bounded buffer ([`with_fine_scope`]) and flush to the
//!   batch's active handles once per solver step instead of taking the
//!   sink lock per site.
//! * **[`export`]** — Chrome `chrome://tracing` trace-event JSON and a
//!   human-readable timeline renderer over flight-recorder dumps.
//!
//! Tracing is observational only: no instrumentation site feeds back
//! into scheduling or numerics, so generated latents are bitwise
//! identical at every level (pinned by `tests/obs.rs` and by the
//! `SMOOTHCACHE_TRACE=fine` CI lane).

pub mod export;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Trace level
// ---------------------------------------------------------------------------

/// Tracing granularity. Ordered: `Off < Coarse < Fine`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// No recording at all; every obs call is a cheap no-op and the
    /// request path allocates nothing for tracing.
    Off = 0,
    /// Request-lifecycle events and spans: submit, queue push/pop,
    /// batch formation, calibration, per-solver-step spans, park /
    /// resume, frame ingress/egress. The always-on default.
    Coarse = 1,
    /// Everything in `Coarse` plus one event per (step, site) reuse
    /// decision, staged through the per-thread buffer.
    Fine = 2,
}

impl TraceLevel {
    /// Parse a `SMOOTHCACHE_TRACE` value. Unrecognised strings are
    /// `None` (the caller falls back to the default).
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s {
            "off" | "0" | "none" => Some(TraceLevel::Off),
            "coarse" | "on" | "1" => Some(TraceLevel::Coarse),
            "fine" | "2" => Some(TraceLevel::Fine),
            _ => None,
        }
    }

    /// Canonical wire name (`off` / `coarse` / `fine`).
    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Coarse => "coarse",
            TraceLevel::Fine => "fine",
        }
    }

    fn from_u8(v: u8) -> TraceLevel {
        match v {
            0 => TraceLevel::Off,
            1 => TraceLevel::Coarse,
            _ => TraceLevel::Fine,
        }
    }
}

const LEVEL_UNSET: u8 = 0xff;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

/// The active [`TraceLevel`]. First call reads `SMOOTHCACHE_TRACE`
/// (default `coarse`); after that it is one relaxed atomic load.
pub fn level() -> TraceLevel {
    let v = LEVEL.load(Ordering::Relaxed);
    if v == LEVEL_UNSET {
        init_level()
    } else {
        TraceLevel::from_u8(v)
    }
}

#[cold]
fn init_level() -> TraceLevel {
    let l = std::env::var("SMOOTHCACHE_TRACE")
        .ok()
        .and_then(|s| TraceLevel::parse(&s))
        .unwrap_or(TraceLevel::Coarse);
    LEVEL.store(l as u8, Ordering::Relaxed);
    l
}

/// Override the trace level for the whole process (benches and tests;
/// servers normally configure via `SMOOTHCACHE_TRACE`). Takes effect
/// for handles created *after* the call — live handles keep recording.
pub fn set_level(l: TraceLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One recorded instant event (`dur_us == 0`) or completed span.
///
/// Names are `&'static str` and payloads are plain words so recording
/// never allocates; the meaning of `a`/`b`/`c`/`f` is per-name
/// (docs/protocol.md §Trace timelines) and [`export`] renders them with
/// their semantic names.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Event name (`submit`, `queue_pop`, `step`, `site`, …).
    pub name: &'static str,
    /// Microseconds since the owning trace started.
    pub t_us: u64,
    /// Span duration in microseconds; 0 for instant events.
    pub dur_us: u64,
    /// First integer payload (per-name meaning).
    pub a: u64,
    /// Second integer payload (per-name meaning).
    pub b: u64,
    /// Third integer payload (per-name meaning).
    pub c: u64,
    /// Float payload (per-name meaning); NaN means "absent" and is
    /// omitted from JSON.
    pub f: f64,
}

impl TraceEvent {
    /// Serialize for the wire timeline / flight-recorder dump.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name)
            .set("t_us", self.t_us)
            .set("dur_us", self.dur_us)
            .set("a", self.a)
            .set("b", self.b)
            .set("c", self.c);
        if self.f.is_finite() {
            j = j.set("f", self.f);
        }
        j
    }
}

/// Terminal outcome of a traced request — decides whether its flight
/// entry is pinned past ring wraparound.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Completed normally.
    Ok,
    /// Batch execution failed.
    Failed,
    /// Cancelled by command or disconnect.
    Cancelled,
    /// Shed or rejected after missing its deadline.
    DeadlineMissed,
    /// Rejected by admission control or the credit window.
    Overloaded,
}

impl Outcome {
    /// Stable wire label.
    pub fn label(self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Failed => "failed",
            Outcome::Cancelled => "cancelled",
            Outcome::DeadlineMissed => "deadline",
            Outcome::Overloaded => "overloaded",
        }
    }

    /// Everything except a clean completion is pinned in the recorder.
    pub fn pinned(self) -> bool {
        self != Outcome::Ok
    }
}

// ---------------------------------------------------------------------------
// Per-request sink + handle
// ---------------------------------------------------------------------------

/// Cap on buffered events per trace; excess events are counted in
/// `dropped` rather than growing without bound (a fine-level 50-step
/// video trajectory stays well under this).
pub const MAX_TRACE_EVENTS: usize = 8192;

struct SinkInner {
    events: Vec<TraceEvent>,
    dropped: u64,
    request_id: u64,
    label: String,
}

struct SinkShared {
    trace_id: u64,
    start: Instant,
    finished: AtomicBool,
    inner: Mutex<SinkInner>,
}

fn lock_inner(s: &SinkShared) -> MutexGuard<'_, SinkInner> {
    // tracing must never take a panic down with it: a poisoned sink
    // just keeps recording
    s.inner.lock().unwrap_or_else(|e| e.into_inner())
}

impl SinkShared {
    fn push(&self, ev: TraceEvent) {
        let mut g = lock_inner(self);
        if g.events.len() >= MAX_TRACE_EVENTS {
            g.dropped += 1;
        } else {
            g.events.push(ev);
        }
    }
}

/// Per-request trace context: trace id + monotonic clock + bounded
/// event buffer. Cloning shares the buffer (`Arc`); the default /
/// [`TraceHandle::off`] handle records nothing and allocates nothing.
#[derive(Clone, Default)]
pub struct TraceHandle(Option<Arc<SinkShared>>);

impl TraceHandle {
    /// A disabled handle — every operation is a no-op.
    pub fn off() -> TraceHandle {
        TraceHandle(None)
    }

    /// Open a trace at the current [`level`]; returns a disabled handle
    /// when tracing is off (the no-allocation path).
    pub fn start() -> TraceHandle {
        if level() == TraceLevel::Off {
            return TraceHandle(None);
        }
        TraceHandle(Some(Arc::new(SinkShared {
            trace_id: NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed),
            start: Instant::now(),
            finished: AtomicBool::new(false),
            inner: Mutex::new(SinkInner {
                events: Vec::new(),
                dropped: 0,
                request_id: 0,
                label: String::new(),
            }),
        })))
    }

    /// True when the handle records.
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// The trace id, or 0 for a disabled handle.
    pub fn id(&self) -> u64 {
        self.0.as_ref().map_or(0, |s| s.trace_id)
    }

    /// ` [trace N]` suffix for typed error messages (empty — and
    /// allocation-free — when disabled), so server log lines and
    /// flight-recorder entries cross-reference.
    pub fn err_tag(&self) -> String {
        match &self.0 {
            None => String::new(),
            Some(s) => format!(" [trace {}]", s.trace_id),
        }
    }

    /// Microseconds since the trace started (0 when disabled).
    pub fn now_us(&self) -> u64 {
        self.0.as_ref().map_or(0, |s| s.start.elapsed().as_micros() as u64)
    }

    /// Attach the coordinator request id and a short label (family /
    /// policy) shown in flight-recorder listings.
    pub fn set_meta(&self, request_id: u64, label: &str) {
        if let Some(s) = &self.0 {
            let mut g = lock_inner(s);
            if request_id != 0 {
                g.request_id = request_id;
            }
            if !label.is_empty() {
                g.label = label.to_string();
            }
        }
    }

    /// Record an instant event.
    pub fn event(&self, name: &'static str, a: u64, b: u64, c: u64, f: f64) {
        if let Some(s) = &self.0 {
            let t_us = s.start.elapsed().as_micros() as u64;
            s.push(TraceEvent { name, t_us, dur_us: 0, a, b, c, f });
        }
    }

    /// Timestamp for a later [`TraceHandle::span_from`] (0 when
    /// disabled).
    pub fn begin(&self) -> u64 {
        self.now_us()
    }

    /// Record a span that started at `t0_us` (from
    /// [`TraceHandle::begin`]) and ends now.
    pub fn span_from(&self, name: &'static str, t0_us: u64, a: u64, b: u64, c: u64, f: f64) {
        if let Some(s) = &self.0 {
            let now = s.start.elapsed().as_micros() as u64;
            s.push(TraceEvent {
                name,
                t_us: t0_us,
                dur_us: now.saturating_sub(t0_us),
                a,
                b,
                c,
                f,
            });
        }
    }

    /// Copy the timeline out (for the `"trace":true` wire response).
    /// `None` when disabled. Works before or after
    /// [`TraceHandle::finish`].
    pub fn snapshot(&self) -> Option<Timeline> {
        let s = self.0.as_ref()?;
        let g = lock_inner(s);
        Some(Timeline {
            trace_id: s.trace_id,
            request_id: g.request_id,
            dropped: g.dropped,
            events: g.events.clone(),
        })
    }

    /// Close the trace with `outcome` and deposit a copy of its
    /// timeline into the global [`FlightRecorder`]. Idempotent: the
    /// first call wins, later calls (the server's catch-all after the
    /// executor already finished) are no-ops.
    pub fn finish(&self, outcome: Outcome) {
        let Some(s) = &self.0 else { return };
        if s.finished.swap(true, Ordering::SeqCst) {
            return;
        }
        let g = lock_inner(s);
        let entry = FlightEntry {
            trace_id: s.trace_id,
            request_id: g.request_id,
            label: g.label.clone(),
            outcome: outcome.label(),
            pinned: outcome.pinned(),
            dropped: g.dropped,
            events: g.events.clone(),
        };
        drop(g);
        recorder().record(entry);
    }
}

impl std::fmt::Debug for TraceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "TraceHandle(off)"),
            Some(s) => write!(f, "TraceHandle({})", s.trace_id),
        }
    }
}

/// A copied-out per-request timeline (the `"trace"` response field).
#[derive(Clone, Debug)]
pub struct Timeline {
    /// Trace id (cross-references ` [trace N]` error suffixes and
    /// flight-recorder entries).
    pub trace_id: u64,
    /// Coordinator request id (0 before assignment).
    pub request_id: u64,
    /// Events dropped past [`MAX_TRACE_EVENTS`].
    pub dropped: u64,
    /// The recorded events, in recording order per thread.
    pub events: Vec<TraceEvent>,
}

impl Timeline {
    /// Serialize as the wire `"trace"` object (docs/protocol.md).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("trace_id", self.trace_id)
            .set("request_id", self.request_id)
            .set("dropped", self.dropped)
            .set("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect()))
    }
}

// ---------------------------------------------------------------------------
// Batch fan-out
// ---------------------------------------------------------------------------

/// The active trace handles of one executing batch. Spans recorded
/// while driving a batch apply to every traced member's timeline;
/// members without tracing cost nothing. Batch spans use a shared
/// `Instant` so one clock read serves all members (each handle has its
/// own epoch, so the span is rebased per handle).
pub struct BatchTrace {
    handles: Vec<TraceHandle>,
}

impl BatchTrace {
    /// Collect the active handles out of a batch's members.
    pub fn new<'a>(handles: impl Iterator<Item = &'a TraceHandle>) -> BatchTrace {
        BatchTrace { handles: handles.filter(|h| h.is_active()).cloned().collect() }
    }

    /// True when at least one member is traced.
    pub fn is_active(&self) -> bool {
        !self.handles.is_empty()
    }

    /// Record an instant event on every traced member.
    pub fn event(&self, name: &'static str, a: u64, b: u64, c: u64, f: f64) {
        for h in &self.handles {
            h.event(name, a, b, c, f);
        }
    }

    /// Start a batch span; `None` when no member is traced (and the
    /// matching [`BatchTrace::span_from`] is then a no-op).
    pub fn begin(&self) -> Option<Instant> {
        if self.is_active() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// End a batch span started with [`BatchTrace::begin`], recording
    /// it on every traced member (rebased onto each member's clock).
    pub fn span_from(
        &self,
        name: &'static str,
        t0: Option<Instant>,
        a: u64,
        b: u64,
        c: u64,
        f: f64,
    ) {
        let Some(t0) = t0 else { return };
        let dur_us = t0.elapsed().as_micros() as u64;
        for h in &self.handles {
            if let Some(s) = &h.0 {
                let now = s.start.elapsed().as_micros() as u64;
                s.push(TraceEvent {
                    name,
                    t_us: now.saturating_sub(dur_us),
                    dur_us,
                    a,
                    b,
                    c,
                    f,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fine-granularity per-thread staging
// ---------------------------------------------------------------------------

/// Cap on per-thread staged fine events per scope (one solver step
/// stages at most `sites` events, far below this).
pub const MAX_SITE_BUF: usize = 4096;

struct FineState {
    start: Instant,
    buf: Vec<TraceEvent>,
    dropped: u64,
}

thread_local! {
    static FINE: RefCell<Option<FineState>> = const { RefCell::new(None) };
}

/// Record one per-site reuse decision at fine granularity
/// (`a`=step, `b`=site index, `c`=1 for compute / 0 for reuse,
/// `f`=last observed drift at the site). Stages into the calling
/// thread's bounded buffer; a no-op outside a [`with_fine_scope`] —
/// in particular, always a no-op below [`TraceLevel::Fine`], so the
/// generate loop pays one atomic load per site when not fine-tracing.
pub fn site_event(step: usize, site: usize, computed: bool, drift: Option<f64>) {
    if level() != TraceLevel::Fine {
        return;
    }
    FINE.with(|slot| {
        let mut g = slot.borrow_mut();
        let Some(st) = g.as_mut() else { return };
        if st.buf.len() >= MAX_SITE_BUF {
            st.dropped += 1;
            return;
        }
        st.buf.push(TraceEvent {
            name: "site",
            t_us: st.start.elapsed().as_micros() as u64,
            dur_us: 0,
            a: step as u64,
            b: site as u64,
            c: computed as u64,
            f: drift.unwrap_or(f64::NAN),
        });
    });
}

struct FineGuard;
impl Drop for FineGuard {
    fn drop(&mut self) {
        FINE.with(|slot| *slot.borrow_mut() = None);
    }
}

/// Run `f` with fine-granularity staging active on this thread, then
/// flush the staged [`site_event`]s into every handle of `bt` (rebased
/// onto each handle's clock). When the level is below `Fine` or no
/// batch member is traced this is exactly `f()` — the executor wraps
/// each `GenSession::step` call in this scope.
pub fn with_fine_scope<R>(bt: &BatchTrace, f: impl FnOnce() -> R) -> R {
    if level() != TraceLevel::Fine || !bt.is_active() {
        return f();
    }
    let _reset = FineGuard;
    FINE.with(|slot| {
        *slot.borrow_mut() =
            Some(FineState { start: Instant::now(), buf: Vec::new(), dropped: 0 });
    });
    let out = f();
    let st = FINE.with(|slot| slot.borrow_mut().take());
    if let Some(st) = st {
        let scope_now = st.start.elapsed().as_micros() as u64;
        for h in &bt.handles {
            let Some(s) = &h.0 else { continue };
            // rebase: scope-relative t → handle-relative t
            let handle_now = s.start.elapsed().as_micros() as u64;
            let offset = handle_now.saturating_sub(scope_now);
            let mut g = lock_inner(s);
            g.dropped += st.dropped;
            for ev in &st.buf {
                if g.events.len() >= MAX_TRACE_EVENTS {
                    g.dropped += 1;
                } else {
                    g.events.push(TraceEvent { t_us: ev.t_us + offset, ..*ev });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Flight recorder
// ---------------------------------------------------------------------------

/// One finished request's retained timeline.
#[derive(Clone, Debug)]
pub struct FlightEntry {
    /// Trace id (matches ` [trace N]` error-message suffixes).
    pub trace_id: u64,
    /// Coordinator request id (0 if the request never reached
    /// admission).
    pub request_id: u64,
    /// Short label (family / policy) set at submission.
    pub label: String,
    /// Terminal [`Outcome::label`].
    pub outcome: &'static str,
    /// True when the entry sits in the pinned lane (errored /
    /// cancelled / deadline-missed requests survive ring wraparound).
    pub pinned: bool,
    /// Events dropped past the per-trace cap.
    pub dropped: u64,
    /// The retained timeline.
    pub events: Vec<TraceEvent>,
}

impl FlightEntry {
    /// Serialize for the `{"cmd":"dump"}` reply.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("trace_id", self.trace_id)
            .set("request_id", self.request_id)
            .set("label", self.label.as_str())
            .set("outcome", self.outcome)
            .set("pinned", self.pinned)
            .set("dropped", self.dropped)
            .set("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect()))
    }
}

struct RecInner {
    cap: usize,
    pinned_cap: usize,
    ring: VecDeque<FlightEntry>,
    pinned: VecDeque<FlightEntry>,
}

/// Process-wide ring of finished-request timelines. Clean completions
/// rotate through a ring of `cap` entries; error outcomes go to a
/// separate `pinned_cap` FIFO lane so a burst of successful traffic
/// cannot evict the failure an operator is about to debug.
pub struct FlightRecorder {
    inner: Mutex<RecInner>,
}

impl FlightRecorder {
    /// Build a recorder with explicit capacities (tests; the global
    /// [`recorder`] sizes itself from `SMOOTHCACHE_FLIGHT_CAP`).
    pub fn with_capacity(cap: usize, pinned_cap: usize) -> FlightRecorder {
        FlightRecorder {
            inner: Mutex::new(RecInner {
                cap: cap.max(1),
                pinned_cap: pinned_cap.max(1),
                ring: VecDeque::new(),
                pinned: VecDeque::new(),
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, RecInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Deposit one finished request. Pinned entries evict only older
    /// pinned entries; ring entries only older ring entries.
    pub fn record(&self, e: FlightEntry) {
        let mut g = self.lock();
        if e.pinned {
            if g.pinned.len() >= g.pinned_cap {
                g.pinned.pop_front();
            }
            g.pinned.push_back(e);
        } else {
            if g.ring.len() >= g.cap {
                g.ring.pop_front();
            }
            g.ring.push_back(e);
        }
    }

    /// Copy every retained entry out, ordered by trace id (pinned and
    /// ring interleaved into one trajectory).
    pub fn dump(&self) -> Vec<FlightEntry> {
        let g = self.lock();
        let mut out: Vec<FlightEntry> = g.pinned.iter().chain(g.ring.iter()).cloned().collect();
        out.sort_by_key(|e| e.trace_id);
        out
    }

    /// Retained entry count (pinned + ring).
    pub fn len(&self) -> usize {
        let g = self.lock();
        g.pinned.len() + g.ring.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every retained entry (tests).
    pub fn clear(&self) {
        let mut g = self.lock();
        g.ring.clear();
        g.pinned.clear();
    }

    /// The `{"cmd":"dump"}` reply body: active level + every entry.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self.dump().iter().map(|e| e.to_json()).collect();
        Json::obj().set("level", level().name()).set("entries", Json::Arr(entries))
    }
}

/// The global flight recorder. Capacity comes from
/// `SMOOTHCACHE_FLIGHT_CAP` (default 64 ring entries; pinned lane is
/// half that, min 8).
pub fn recorder() -> &'static FlightRecorder {
    static R: OnceLock<FlightRecorder> = OnceLock::new();
    R.get_or_init(|| {
        let cap = std::env::var("SMOOTHCACHE_FLIGHT_CAP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(64);
        FlightRecorder::with_capacity(cap, (cap / 2).max(8))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u64, pinned: bool) -> FlightEntry {
        FlightEntry {
            trace_id: id,
            request_id: id,
            label: "t".into(),
            outcome: if pinned { "failed" } else { "ok" },
            pinned,
            dropped: 0,
            events: vec![TraceEvent {
                name: "submit",
                t_us: 1,
                dur_us: 0,
                a: id,
                b: 0,
                c: 0,
                f: f64::NAN,
            }],
        }
    }

    #[test]
    fn ring_wraps_and_pins_survive() {
        let r = FlightRecorder::with_capacity(4, 2);
        for i in 0..10 {
            r.record(entry(i, false));
        }
        r.record(entry(100, true));
        for i in 10..20 {
            r.record(entry(i, false));
        }
        let d = r.dump();
        // ring holds the last 4 unpinned; the pinned entry survived 10
        // further unpinned inserts
        assert_eq!(d.len(), 5);
        assert!(d.iter().any(|e| e.trace_id == 100 && e.pinned));
        let ring_ids: Vec<u64> =
            d.iter().filter(|e| !e.pinned).map(|e| e.trace_id).collect();
        assert_eq!(ring_ids, vec![16, 17, 18, 19]);
    }

    #[test]
    fn pinned_lane_is_bounded_fifo() {
        let r = FlightRecorder::with_capacity(4, 2);
        for i in 0..5 {
            r.record(entry(i, true));
        }
        let ids: Vec<u64> = r.dump().iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![3, 4]);
    }

    #[test]
    fn event_json_omits_nan_float() {
        let ev =
            TraceEvent { name: "step", t_us: 5, dur_us: 2, a: 1, b: 2, c: 3, f: f64::NAN };
        let j = ev.to_json();
        assert!(j.get("f").is_none());
        assert_eq!(j.get("name").unwrap().as_str(), Some("step"));
        let ev2 = TraceEvent { f: 0.5, ..ev };
        assert_eq!(ev2.to_json().get("f").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn off_handle_is_inert() {
        let h = TraceHandle::off();
        assert!(!h.is_active());
        assert_eq!(h.id(), 0);
        assert_eq!(h.err_tag(), "");
        h.event("submit", 1, 2, 3, 0.0);
        h.span_from("step", h.begin(), 0, 0, 0, 0.0);
        h.finish(Outcome::Ok);
        assert!(h.snapshot().is_none());
    }

    #[test]
    fn handle_records_and_bounds() {
        let h = TraceHandle(Some(Arc::new(SinkShared {
            trace_id: 7,
            start: Instant::now(),
            finished: AtomicBool::new(false),
            inner: Mutex::new(SinkInner {
                events: Vec::new(),
                dropped: 0,
                request_id: 0,
                label: String::new(),
            }),
        })));
        h.set_meta(42, "image/no-cache");
        for i in 0..(MAX_TRACE_EVENTS + 10) {
            h.event("submit", i as u64, 0, 0, f64::NAN);
        }
        let t = h.snapshot().unwrap();
        assert_eq!(t.trace_id, 7);
        assert_eq!(t.request_id, 42);
        assert_eq!(t.events.len(), MAX_TRACE_EVENTS);
        assert_eq!(t.dropped, 10);
        assert!(h.err_tag().contains("trace 7"));
    }

    #[test]
    fn level_parse_roundtrip() {
        for l in [TraceLevel::Off, TraceLevel::Coarse, TraceLevel::Fine] {
            assert_eq!(TraceLevel::parse(l.name()), Some(l));
        }
        assert_eq!(TraceLevel::parse("bogus"), None);
        assert!(TraceLevel::Off < TraceLevel::Coarse);
        assert!(TraceLevel::Coarse < TraceLevel::Fine);
    }
}
