//! Shared experiment drivers for the benches and examples: synthetic
//! reference corpora (the FID-reference "datasets"), conditioning
//! samplers, batched evaluation-set generation, and table-row metric
//! bundles. Every table/figure bench builds on this module so all rows
//! are computed identically.

use crate::util::error::Result;

use crate::cache::plan::PlanRef;
use crate::cache::sample_cond;
use crate::model::{Cond, Engine, FamilyManifest};
use crate::pipeline::{generate, GenConfig, GenStats};
use crate::solvers::SolverKind;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Reference corpora (DESIGN.md §3 dataset substitutions)
// ---------------------------------------------------------------------------

/// The image family's training corpus (port of python/compile/data.py):
/// 10-class Gaussian-blob latents. Used as the FID-reference set.
pub fn image_corpus(n: usize, seed: u64) -> (Tensor, Vec<i32>) {
    let (h, w) = (16usize, 16usize);
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * h * w * 4);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let k = rng.below(10) as i32;
        labels.push(k);
        let ang = 2.0 * std::f64::consts::PI * k as f64 / 10.0;
        let cx = w as f64 / 2.0 + 5.0 * ang.cos() + rng.normal() * 0.4;
        let cy = h as f64 / 2.0 + 5.0 * ang.sin() + rng.normal() * 0.4;
        let amp = rng.range_f64(0.8, 1.2);
        let ring_r = 2.0 + 0.4 * k as f64;
        for yy in 0..h {
            for xx in 0..w {
                let r2 = (xx as f64 - cx).powi(2) + (yy as f64 - cy).powi(2);
                let blob = amp * (-r2 / (2.0 * 1.5 * 1.5)).exp();
                let ring = amp * (-((r2.sqrt() - ring_r).powi(2)) / (2.0 * 0.8 * 0.8)).exp();
                data.push((2.0 * blob - 1.0) as f32);
                data.push(((xx as f64 - cx) / w as f64 * blob * 4.0) as f32);
                data.push(((yy as f64 - cy) / h as f64 * blob * 4.0) as f32);
                data.push((2.0 * ring - 1.0) as f32);
            }
        }
    }
    (Tensor::new(vec![n, h, w, 4], data), labels)
}

/// Synthetic audio-latent corpus: harmonic envelopes over 64 frames × 8
/// channels (stands in for the AudioCaps/MusicCaps evaluation sets).
pub fn audio_corpus(n: usize, seed: u64) -> Tensor {
    let (t, c) = (64usize, 8usize);
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * t * c);
    for _ in 0..n {
        let f0 = rng.range_f64(0.05, 0.4);
        let decay = rng.range_f64(0.01, 0.05);
        let phase = rng.range_f64(0.0, std::f64::consts::TAU);
        for ti in 0..t {
            let env = (-(ti as f64) * decay).exp();
            for ci in 0..c {
                let harm = (ci + 1) as f64;
                let v = env * (f0 * harm * ti as f64 * std::f64::consts::TAU + phase).sin()
                    / harm.sqrt();
                data.push(v as f32);
            }
        }
    }
    Tensor::new(vec![n, t, c], data)
}

/// Synthetic video-latent corpus: a blob translating across frames
/// (stands in for the VBench reference distribution).
pub fn video_corpus(n: usize, seed: u64) -> Tensor {
    let (f, h, w, c) = (4usize, 8usize, 8usize, 4usize);
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * f * h * w * c);
    for _ in 0..n {
        let x0 = rng.range_f64(1.0, 6.0);
        let y0 = rng.range_f64(1.0, 6.0);
        let vx = rng.range_f64(-1.0, 1.0);
        let vy = rng.range_f64(-1.0, 1.0);
        for fi in 0..f {
            let cx = x0 + vx * fi as f64;
            let cy = y0 + vy * fi as f64;
            for yy in 0..h {
                for xx in 0..w {
                    let r2 = (xx as f64 - cx).powi(2) + (yy as f64 - cy).powi(2);
                    let blob = (-r2 / 3.0).exp();
                    for ci in 0..c {
                        data.push((blob * (1.0 + ci as f64 * 0.2) - 0.5) as f32);
                    }
                }
            }
        }
    }
    Tensor::new(vec![n, f, h, w, c], data)
}

pub fn corpus_for(family: &str, n: usize, seed: u64) -> Tensor {
    match family {
        "image" => image_corpus(n, seed).0,
        "audio" => audio_corpus(n, seed),
        "video" => video_corpus(n, seed),
        other => panic!("unknown family {other}"),
    }
}

// ---------------------------------------------------------------------------
// Evaluation-set generation
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub family: String,
    pub solver: SolverKind,
    pub steps: usize,
    pub cfg_scale: f32,
    pub n_samples: usize,
    pub batch: usize,
    pub base_seed: u64,
    /// GEMM compute threads to pin for this evaluation (0 = inherit the
    /// process-wide setting). Results are bitwise invariant to this —
    /// it only changes wall time (`tests/parallel_parity.rs`).
    pub threads: usize,
}

impl EvalConfig {
    pub fn new(family: &str, solver: SolverKind, steps: usize) -> EvalConfig {
        EvalConfig {
            family: family.into(),
            solver,
            steps,
            cfg_scale: 1.0,
            n_samples: 32,
            batch: 4,
            base_seed: 1234,
            threads: 0,
        }
    }

    pub fn with_threads(mut self, n: usize) -> EvalConfig {
        self.threads = n;
        self
    }
}

/// Fixed per-index conditionings so every schedule sees identical
/// trajectories (paired comparisons, as the paper's LPIPS/PSNR need).
pub fn eval_conds(fm: &FamilyManifest, n: usize, seed: u64) -> Vec<Cond> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| sample_cond(&mut rng, fm.num_classes, fm.vocab, fm.cond_len, false))
        .collect()
}

#[derive(Clone, Debug, Default)]
pub struct EvalStats {
    pub wall_seconds: f64,
    pub per_sample_seconds: f64,
    pub gen: GenStats,
}

/// Generate `cfg.n_samples` samples under one cache plan (or runtime
/// planner), batching at `cfg.batch`. Returns the stacked sample set
/// and aggregate stats. Honors `cfg.threads` by pinning the GEMM pool
/// for the duration.
pub fn generate_set(
    engine: &Engine,
    cfg: &EvalConfig,
    conds: &[Cond],
    plan: PlanRef<'_>,
) -> Result<(Tensor, EvalStats)> {
    if cfg.threads > 0 {
        return crate::tensor::gemm::with_threads(cfg.threads, || {
            generate_set_inner(engine, cfg, conds, plan)
        });
    }
    generate_set_inner(engine, cfg, conds, plan)
}

fn generate_set_inner(
    engine: &Engine,
    cfg: &EvalConfig,
    conds: &[Cond],
    plan: PlanRef<'_>,
) -> Result<(Tensor, EvalStats)> {
    assert_eq!(conds.len(), cfg.n_samples);
    let fm = engine.family_manifest(&cfg.family)?.clone();
    let mut outputs: Vec<Tensor> = Vec::with_capacity(cfg.n_samples);
    let mut stats = EvalStats::default();
    let t0 = std::time::Instant::now();
    let mut i = 0;
    while i < cfg.n_samples {
        let b = cfg.batch.min(cfg.n_samples - i);
        let mut cond = conds[i].clone();
        for c in &conds[i + 1..i + b] {
            cond = cond.cat(c);
        }
        // pad the tail batch up to cfg.batch so one executable serves all
        let cond = cond.pad_to(cfg.batch, fm.cond_len);
        let gen_cfg = GenConfig::new(&cfg.family, cfg.solver, cfg.steps)
            .with_cfg(cfg.cfg_scale)
            .with_seed(cfg.base_seed.wrapping_add(i as u64));
        let out = generate(engine, &gen_cfg, &cond, plan, None)?;
        for j in 0..b {
            outputs.push(out.latent.sample(j));
        }
        stats.gen.branch_computes += out.stats.branch_computes;
        stats.gen.branch_reuses += out.stats.branch_reuses;
        stats.gen.steps = out.stats.steps;
        i += b;
    }
    stats.wall_seconds = t0.elapsed().as_secs_f64();
    stats.per_sample_seconds = stats.wall_seconds / cfg.n_samples as f64;
    let refs: Vec<&Tensor> = outputs.iter().collect();
    Ok((Tensor::cat0(&refs), stats))
}

/// Mean ± std formatting used in every table (the paper reports 5-trial
/// mean ± std; we run fewer trials but keep the format).
pub fn fmt_pm(mean: f64, std: f64, prec: usize) -> String {
    format!("{mean:.prec$} ±{std:.prec$}")
}

/// Mean/std over a set of trial values.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let m = xs.iter().sum::<f64>() / n;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
    (m, v.sqrt())
}

/// Video helpers for the VBench-proxy: mean SSIM between consecutive
/// frames (temporal consistency component).
pub fn temporal_consistency(video_set: &Tensor) -> f64 {
    // [n, F, H, W, C]
    let n = video_set.dim0();
    let f = video_set.shape[1];
    let frame_len: usize = video_set.shape[2..].iter().product();
    let mut total = 0.0;
    let mut count = 0;
    for i in 0..n {
        let s = video_set.sample(i);
        for fi in 0..f - 1 {
            let a = Tensor::new(
                vec![frame_len],
                s.data[fi * frame_len..(fi + 1) * frame_len].to_vec(),
            );
            let b = Tensor::new(
                vec![frame_len],
                s.data[(fi + 1) * frame_len..(fi + 2) * frame_len].to_vec(),
            );
            total += crate::quality::ssim(&a, &b);
            count += 1;
        }
    }
    total / count as f64
}

/// VBench-proxy (DESIGN.md §3): 100 · (0.5·temporal-consistency(normalised)
/// + 0.5·prompt-adherence) where adherence is the CLAP-proxy against the
/// no-cache generations.
pub fn vbench_proxy(
    fx: &crate::quality::FeatureExtractor,
    reference_set: &Tensor,
    test_set: &Tensor,
) -> f64 {
    let tc = 0.5 * (temporal_consistency(test_set) + 1.0); // [-1,1] → [0,1]
    let adherence = 0.5 * (crate::quality::clap_proxy(fx, reference_set, test_set) + 1.0);
    100.0 * (0.5 * tc + 0.5 * adherence)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpora_shapes_and_determinism() {
        let (im, labels) = image_corpus(4, 1);
        assert_eq!(im.shape, vec![4, 16, 16, 4]);
        assert_eq!(labels.len(), 4);
        assert_eq!(image_corpus(4, 1).0.data, im.data);
        assert_eq!(audio_corpus(3, 2).shape, vec![3, 64, 8]);
        assert_eq!(video_corpus(2, 3).shape, vec![2, 4, 8, 8, 4]);
    }

    #[test]
    fn image_corpus_is_class_structured() {
        // two samples of the same class are closer than different classes
        let (set, labels) = image_corpus(64, 7);
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..16 {
            for j in (i + 1)..16 {
                let d = set.sample(i).sub(&set.sample(j)).l2();
                if labels[i] == labels[j] {
                    same.push(d);
                } else {
                    diff.push(d);
                }
            }
        }
        if !same.is_empty() && !diff.is_empty() {
            let ms = same.iter().sum::<f64>() / same.len() as f64;
            let md = diff.iter().sum::<f64>() / diff.len() as f64;
            assert!(ms < md, "same-class {ms} vs diff-class {md}");
        }
    }

    #[test]
    fn temporal_consistency_of_static_video_is_high() {
        // constant-across-frames video → consecutive-frame SSIM ≈ 1
        let mut rng = Rng::new(5);
        let frame = Tensor::randn(vec![1, 1, 8, 8, 4], &mut rng);
        let mut data = Vec::new();
        for _ in 0..4 {
            data.extend_from_slice(&frame.data);
        }
        let vid = Tensor::new(vec![1, 4, 8, 8, 4], data);
        assert!(temporal_consistency(&vid) > 0.99);
        // random-per-frame video → much lower
        let noise = Tensor::randn(vec![1, 4, 8, 8, 4], &mut rng);
        assert!(temporal_consistency(&noise) < 0.5);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
