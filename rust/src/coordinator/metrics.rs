//! Serving metrics: lock-free counters + log-bucketed latency histograms.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Log-scale latency histogram, 1 ms … ~2000 s. Thread-safe, lock-free.
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    /// sum of observations in microseconds.
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram with 1ms·2^i bucket bounds.
    pub fn new() -> Histogram {
        // 1ms · 2^i buckets
        let bounds: Vec<f64> = (0..22).map(|i| 0.001 * 2f64.powi(i)).collect();
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, buckets, sum_us: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    /// Record one latency observation, in seconds.
    pub fn observe(&self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / 1e6 / c as f64
        }
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
            }
        }
        f64::INFINITY
    }
}

/// Coordinator-wide counters.
#[derive(Default)]
pub struct Metrics {
    /// Size of the executor replica pool (set once at startup; 1 when
    /// the backend cannot replicate).
    pub executor_replicas: AtomicU64,
    /// Requests accepted by [`super::Coordinator::submit`].
    pub requests_submitted: AtomicU64,
    /// Requests answered with a successful response (each request is
    /// answered exactly once; see `tests/coordinator_props.rs`).
    pub requests_completed: AtomicU64,
    /// Requests answered with an execution error (admission rejections
    /// count under [`Metrics::queue_rejections`] instead).
    pub requests_failed: AtomicU64,
    /// Requests answered with a `cancelled:` error — cancelled while
    /// buffered, queued, or between solver steps of an in-flight
    /// generation (each counted exactly once, wherever it was shed).
    pub requests_cancelled: AtomicU64,
    /// Deadline misses: reject-late requests answered with a
    /// `deadline:` error plus best-effort responses delivered late
    /// (flagged `deadline_missed` on the [`super::Response`]).
    pub deadline_missed: AtomicU64,
    /// Solver steps executed across all batches (the coarse progress
    /// pulse: it advancing means the pool is making forward progress).
    pub steps_executed: AtomicU64,
    /// Batches pulled from the work queue and executed.
    pub batches_executed: AtomicU64,
    /// Padding slots added to reach an AOT-compiled batch size.
    pub padded_slots: AtomicU64,
    /// Branch executions actually computed across all generations.
    pub branch_computes: AtomicU64,
    /// Branch executions skipped by reusing a cached delta.
    pub branch_reuses: AtomicU64,
    /// Calibration passes run (once per cold (family, solver, steps)).
    pub calibrations: AtomicU64,
    /// Plan-store lookups answered from the `PlanKey → CachePlan`
    /// cache (curve-needing policies resolved without rebuilding).
    pub plan_cache_hits: AtomicU64,
    /// Plan-store lookups that built (and cached) a fresh `CachePlan`.
    pub plan_cache_misses: AtomicU64,
    /// Requests rejected at work-queue admission because the queue was
    /// full (`--queue-depth`); surfaced to clients as `overloaded:`
    /// errors (docs/protocol.md).
    pub queue_rejections: AtomicU64,
    /// Requests currently waiting in the shared work queue (gauge,
    /// refreshed on every push/pop).
    pub queue_depth: AtomicU64,
    /// High-water mark of [`Metrics::queue_depth`] since startup.
    pub queue_peak_depth: AtomicU64,
    /// end-to-end (submit → response) latency.
    pub e2e_latency: Histogram,
    /// queueing delay (submit → batch execution start; includes batcher
    /// grouping time).
    pub queue_latency: Histogram,
    /// work-queue wait per batch (queue admission → pulled by an
    /// executor) — the scheduler's own contribution to latency,
    /// reported next to [`Metrics::exec_latency`] by the serving
    /// benches.
    pub queue_wait: Histogram,
    /// model execution time per batch.
    pub exec_latency: Histogram,
    /// per-solver-step execution time (one observation per step per
    /// batch) — the granularity cancellation and streaming progress
    /// operate at: a cancel lands within roughly one `step_mean`.
    pub step_latency: Histogram,
    /// Preemptions: batch-class generations parked at a step boundary
    /// because interactive work was waiting (docs/adr/007).
    pub preemptions: AtomicU64,
    /// Parked sessions resumed by an executor (≤ [`Metrics::preemptions`];
    /// the gap is sessions still parked or cancelled while parked).
    pub session_resumes: AtomicU64,
    /// Sessions currently parked in the work queue (gauge).
    pub parked_sessions: AtomicU64,
    /// High-water mark of [`Metrics::parked_sessions`] since startup.
    pub parked_peak: AtomicU64,
    /// park → resume latency per parked session (how long preempted
    /// work waited before an executor picked it back up).
    pub resume_latency: Histogram,
    /// end-to-end latency of interactive-class requests (the class the
    /// preemptive scheduler protects; per-class p50/p95/p99 in
    /// [`Metrics::summary`]).
    pub e2e_interactive: Histogram,
    /// end-to-end latency of batch-class requests (the preemptible
    /// class — expect a longer tail, bounded by the aging rule).
    pub e2e_batch: Histogram,
    /// work-queue wait of interactive-class batches.
    pub qwait_interactive: Histogram,
    /// work-queue wait of batch-class batches (queue admission → first
    /// pulled; resume waits are under [`Metrics::resume_latency`]).
    pub qwait_batch: Histogram,
    /// Protocol v2 (`SMC2` framed) connections accepted since startup
    /// (docs/adr/008).
    pub v2_connections: AtomicU64,
    /// v2 `request` frames rejected because the connection's credit
    /// window (`--conn-inflight`) was exhausted; surfaced to clients as
    /// typed `overloaded:` errors, distinct from
    /// [`Metrics::queue_rejections`] (queue admission).
    pub v2_credit_rejections: AtomicU64,
}

impl Metrics {
    /// Increment a counter by one.
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `v` to a counter.
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Read a counter.
    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Overwrite a gauge (last-writer-wins; used for queue depth).
    pub fn set(gauge: &AtomicU64, v: u64) {
        gauge.store(v, Ordering::Relaxed);
    }

    /// Raise a high-water-mark gauge to at least `v`.
    pub fn raise(gauge: &AtomicU64, v: u64) {
        gauge.fetch_max(v, Ordering::Relaxed);
    }

    /// Mean request batch occupancy (real requests / executed slots).
    pub fn occupancy(&self) -> f64 {
        let done = Self::get(&self.requests_completed);
        let padded = Self::get(&self.padded_slots);
        if done + padded == 0 {
            1.0
        } else {
            done as f64 / (done + padded) as f64
        }
    }

    /// One-line human-readable snapshot of every counter (the payload
    /// of the server's `{"cmd": "metrics"}` command; field list in
    /// docs/protocol.md).
    pub fn summary(&self) -> String {
        format!(
            "workers={} requests={} completed={} failed={} cancelled={} dl_miss={} \
             rejected={} batches={} qdepth={} qpeak={} occupancy={:.2} plan_hits={} \
             plan_miss={} e2e_mean={:.3}s e2e_p95={:.3}s queue_mean={:.3}s \
             qwait_mean={:.3}s qwait_p95={:.3}s exec_mean={:.3}s steps={} \
             step_mean={:.4}s skips={}/{} preempt={} resumes={} parked={} \
             park_peak={} resume_mean={:.3}s e2e_int_p50={:.3}s e2e_int_p95={:.3}s \
             e2e_int_p99={:.3}s e2e_bat_p50={:.3}s e2e_bat_p95={:.3}s \
             e2e_bat_p99={:.3}s qwait_int_mean={:.3}s qwait_bat_mean={:.3}s \
             v2_conns={} v2_credit_rej={}",
            Self::get(&self.executor_replicas).max(1),
            Self::get(&self.requests_submitted),
            Self::get(&self.requests_completed),
            Self::get(&self.requests_failed),
            Self::get(&self.requests_cancelled),
            Self::get(&self.deadline_missed),
            Self::get(&self.queue_rejections),
            Self::get(&self.batches_executed),
            Self::get(&self.queue_depth),
            Self::get(&self.queue_peak_depth),
            self.occupancy(),
            Self::get(&self.plan_cache_hits),
            Self::get(&self.plan_cache_misses),
            self.e2e_latency.mean(),
            self.e2e_latency.quantile(0.95),
            self.queue_latency.mean(),
            self.queue_wait.mean(),
            self.queue_wait.quantile(0.95),
            self.exec_latency.mean(),
            Self::get(&self.steps_executed),
            self.step_latency.mean(),
            Self::get(&self.branch_reuses),
            Self::get(&self.branch_computes) + Self::get(&self.branch_reuses),
            Self::get(&self.preemptions),
            Self::get(&self.session_resumes),
            Self::get(&self.parked_sessions),
            Self::get(&self.parked_peak),
            self.resume_latency.mean(),
            self.e2e_interactive.quantile(0.50),
            self.e2e_interactive.quantile(0.95),
            self.e2e_interactive.quantile(0.99),
            self.e2e_batch.quantile(0.50),
            self.e2e_batch.quantile(0.95),
            self.e2e_batch.quantile(0.99),
            self.qwait_interactive.mean(),
            self.qwait_batch.mean(),
            Self::get(&self.v2_connections),
            Self::get(&self.v2_credit_rejections),
        )
    }

    /// The same snapshot as [`Metrics::summary`], as structured JSON
    /// (the server's `{"cmd":"metrics","format":"json"}` payload;
    /// field set pinned by `tests/serving.rs`). Keys match the
    /// `key=value` names of the human summary one for one; the string's
    /// `skips=X/Y` pair becomes `skips` and `branch_total`. Quantiles a
    /// histogram cannot bound are reported as `-1` (JSON has no ∞).
    pub fn summary_json(&self) -> Json {
        fn fin(x: f64) -> f64 {
            if x.is_finite() {
                x
            } else {
                -1.0
            }
        }
        Json::obj()
            .set("workers", Self::get(&self.executor_replicas).max(1))
            .set("requests", Self::get(&self.requests_submitted))
            .set("completed", Self::get(&self.requests_completed))
            .set("failed", Self::get(&self.requests_failed))
            .set("cancelled", Self::get(&self.requests_cancelled))
            .set("dl_miss", Self::get(&self.deadline_missed))
            .set("rejected", Self::get(&self.queue_rejections))
            .set("batches", Self::get(&self.batches_executed))
            .set("qdepth", Self::get(&self.queue_depth))
            .set("qpeak", Self::get(&self.queue_peak_depth))
            .set("occupancy", self.occupancy())
            .set("plan_hits", Self::get(&self.plan_cache_hits))
            .set("plan_miss", Self::get(&self.plan_cache_misses))
            .set("e2e_mean", self.e2e_latency.mean())
            .set("e2e_p95", fin(self.e2e_latency.quantile(0.95)))
            .set("queue_mean", self.queue_latency.mean())
            .set("qwait_mean", self.queue_wait.mean())
            .set("qwait_p95", fin(self.queue_wait.quantile(0.95)))
            .set("exec_mean", self.exec_latency.mean())
            .set("steps", Self::get(&self.steps_executed))
            .set("step_mean", self.step_latency.mean())
            .set("skips", Self::get(&self.branch_reuses))
            .set(
                "branch_total",
                Self::get(&self.branch_computes) + Self::get(&self.branch_reuses),
            )
            .set("preempt", Self::get(&self.preemptions))
            .set("resumes", Self::get(&self.session_resumes))
            .set("parked", Self::get(&self.parked_sessions))
            .set("park_peak", Self::get(&self.parked_peak))
            .set("resume_mean", self.resume_latency.mean())
            .set("e2e_int_p50", fin(self.e2e_interactive.quantile(0.50)))
            .set("e2e_int_p95", fin(self.e2e_interactive.quantile(0.95)))
            .set("e2e_int_p99", fin(self.e2e_interactive.quantile(0.99)))
            .set("e2e_bat_p50", fin(self.e2e_batch.quantile(0.50)))
            .set("e2e_bat_p95", fin(self.e2e_batch.quantile(0.95)))
            .set("e2e_bat_p99", fin(self.e2e_batch.quantile(0.99)))
            .set("qwait_int_mean", self.qwait_interactive.mean())
            .set("qwait_bat_mean", self.qwait_batch.mean())
            .set("v2_conns", Self::get(&self.v2_connections))
            .set("v2_credit_rej", Self::get(&self.v2_credit_rejections))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.observe(0.010);
        }
        for _ in 0..10 {
            h.observe(1.0);
        }
        assert_eq!(h.count(), 110);
        let mean = h.mean();
        assert!((mean - (100.0 * 0.01 + 10.0) / 110.0).abs() < 1e-3, "{mean}");
        assert!(h.quantile(0.5) <= 0.016);
        assert!(h.quantile(0.99) >= 0.5);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn occupancy_counts_padding() {
        let m = Metrics::default();
        Metrics::add(&m.requests_completed, 6);
        Metrics::add(&m.padded_slots, 2);
        assert!((m.occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_submitted);
        assert!(m.summary().contains("requests=1"));
    }

    #[test]
    fn summary_reports_plan_cache_counters() {
        let m = Metrics::default();
        Metrics::add(&m.plan_cache_hits, 4);
        Metrics::inc(&m.plan_cache_misses);
        let s = m.summary();
        assert!(s.contains("plan_hits=4"), "{s}");
        assert!(s.contains("plan_miss=1"), "{s}");
    }

    #[test]
    fn summary_reports_cancellation_and_step_counters() {
        let m = Metrics::default();
        Metrics::add(&m.requests_cancelled, 2);
        Metrics::inc(&m.deadline_missed);
        Metrics::add(&m.steps_executed, 50);
        m.step_latency.observe(0.002);
        let s = m.summary();
        assert!(s.contains("cancelled=2"), "{s}");
        assert!(s.contains("dl_miss=1"), "{s}");
        assert!(s.contains("steps=50"), "{s}");
        assert!(s.contains("step_mean=0.0020s"), "{s}");
    }

    #[test]
    fn summary_reports_queue_counters() {
        let m = Metrics::default();
        Metrics::add(&m.queue_rejections, 3);
        Metrics::set(&m.queue_depth, 5);
        Metrics::raise(&m.queue_peak_depth, 5);
        Metrics::raise(&m.queue_peak_depth, 2); // raise never lowers
        m.queue_wait.observe(0.25);
        let s = m.summary();
        assert!(s.contains("rejected=3"), "{s}");
        assert!(s.contains("qdepth=5"), "{s}");
        assert!(s.contains("qpeak=5"), "{s}");
        assert!(s.contains("qwait_mean=0.250s"), "{s}");
    }

    #[test]
    fn summary_reports_preemption_counters() {
        let m = Metrics::default();
        Metrics::add(&m.preemptions, 3);
        Metrics::add(&m.session_resumes, 2);
        Metrics::set(&m.parked_sessions, 1);
        Metrics::raise(&m.parked_peak, 2);
        m.resume_latency.observe(0.125);
        let s = m.summary();
        assert!(s.contains("preempt=3"), "{s}");
        assert!(s.contains("resumes=2"), "{s}");
        assert!(s.contains("parked=1"), "{s}");
        assert!(s.contains("park_peak=2"), "{s}");
        assert!(s.contains("resume_mean=0.125s"), "{s}");
    }

    #[test]
    fn summary_reports_v2_counters() {
        let m = Metrics::default();
        Metrics::add(&m.v2_connections, 2);
        Metrics::inc(&m.v2_credit_rejections);
        let s = m.summary();
        assert!(s.contains("v2_conns=2"), "{s}");
        assert!(s.contains("v2_credit_rej=1"), "{s}");
    }

    #[test]
    fn summary_json_mirrors_summary_fields() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_submitted);
        Metrics::add(&m.branch_reuses, 3);
        Metrics::add(&m.branch_computes, 5);
        m.e2e_latency.observe(0.010);
        let j = m.summary_json();
        assert_eq!(j.get("requests").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("skips").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("branch_total").unwrap().as_u64(), Some(8));
        assert!(j.get("e2e_mean").unwrap().as_f64().unwrap() > 0.0);
        // every key=value field of the human summary has a JSON mirror
        // (skips=X/Y is split into `skips` + `branch_total`)
        for field in m.summary().split_whitespace() {
            let key = field.split('=').next().unwrap();
            assert!(j.get(key).is_some(), "summary key {key} missing from summary_json");
        }
        // the JSON round-trips through the crate's own parser
        let text = j.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("requests").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn summary_reports_per_class_latency_percentiles() {
        let m = Metrics::default();
        for _ in 0..100 {
            m.e2e_interactive.observe(0.010);
        }
        m.e2e_batch.observe(4.0);
        m.qwait_interactive.observe(0.002);
        m.qwait_batch.observe(0.5);
        let s = m.summary();
        assert!(s.contains("e2e_int_p50="), "{s}");
        assert!(s.contains("e2e_int_p95="), "{s}");
        assert!(s.contains("e2e_int_p99="), "{s}");
        assert!(s.contains("e2e_bat_p99="), "{s}");
        assert!(s.contains("qwait_int_mean=0.002s"), "{s}");
        assert!(s.contains("qwait_bat_mean=0.500s"), "{s}");
        // the two classes are tracked independently
        assert!(m.e2e_interactive.quantile(0.99) < 0.1);
        assert!(m.e2e_batch.quantile(0.50) >= 4.0);
    }
}
