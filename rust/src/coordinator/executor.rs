//! The executor replicas: each executor thread owns its *own* engine
//! (and thus its own backend instance — PJRT handles are thread-bound,
//! so that backend runs exactly one replica; the reference backend
//! replicates freely), pulls batches from the coordinator's shared
//! [`WorkQueue`] whenever it goes idle,
//! resolves caching policies to concrete [`CachePlan`]s through the
//! pool-shared [`PlanStore`] (calibrating on demand, exactly once per
//! configuration across all replicas) — or drives a
//! [`crate::cache::StepPlanner`] at runtime for dynamic policies — and
//! runs batched generations.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::util::error::Result;

use super::cancel::{reply_dead, DeadlinePolicy, Progress};
use super::metrics::Metrics;
use super::queue::WorkQueue;
use super::request::{InFlight, Request, Response};
use crate::cache::plan::{CachePlan, PlanCtx, PlanRef};
use crate::cache::{calibrate, CalibrationConfig, ErrorCurves};
use crate::model::Engine;
use crate::pipeline::{GenConfig, GenSession};
use crate::solvers::SolverRun;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-replica configuration (cloned into every executor thread).
#[derive(Clone)]
pub struct ExecutorConfig {
    /// artifact directory the replica's engine opens (manifest,
    /// weights, executables — or nothing, for the builtin geometry).
    pub artifacts_dir: std::path::PathBuf,
    /// families to preload at startup (lazy for the rest).
    pub preload: Vec<String>,
    /// calibration samples for on-demand SmoothCache calibration
    /// (paper: 10; servers may trade a few for startup time).
    pub calib_samples: usize,
    /// seed for on-demand calibration passes.
    pub calib_seed: u64,
    /// optional directory with pre-computed calibration curves
    /// (artifacts/calibration/{family}_{solver}_{steps}.json).
    pub curves_dir: Option<std::path::PathBuf>,
}

/// One [`PlanStore`] shared by every executor replica: calibration is
/// expensive, so the first replica to need a (family, solver, steps)
/// configuration calibrates while the others block on the mutex and
/// then read the cached curves — the "calibrate once per config"
/// serving contract holds at any pool size.
pub type SharedPlanStore = Arc<Mutex<PlanStore>>;

/// Lock the shared store, recovering from a replica that panicked while
/// holding it (the store's maps are always left consistent: entries are
/// inserted fully-formed).
pub fn lock_store(store: &SharedPlanStore) -> MutexGuard<'_, PlanStore> {
    store.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Cache key for one resolved plan: the full configuration a
/// [`CachePlan`] is specific to.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// model family the plan was built for.
    pub family: String,
    /// solver name (calibrated plans are trajectory-specific).
    pub solver: String,
    /// sampling steps the plan spans.
    pub steps: usize,
    /// canonical policy wire string.
    pub policy: String,
}

/// Caches calibration curves and resolved [`CachePlan`]s across
/// requests: one `PlanKey → Arc<CachePlan>` map for every policy shape
/// (this replaced the pre-plan-API trio of grouped-schedule and
/// per-site-map caches keyed by ad-hoc tuples). Invariant: entries are
/// only ever inserted fully-formed, so any observable state is
/// consistent even after a panic mid-request.
pub struct PlanStore {
    /// calibration samples for on-demand calibration (see
    /// [`ExecutorConfig::calib_samples`]).
    pub calib_samples: usize,
    /// seed for on-demand calibration passes.
    pub calib_seed: u64,
    /// optional directory of pre-computed calibration curves, checked
    /// before calibrating.
    pub curves_dir: Option<std::path::PathBuf>,
    curves: HashMap<(String, String, usize), ErrorCurves>,
    plans: HashMap<PlanKey, Arc<CachePlan>>,
}

impl PlanStore {
    /// An empty store with the given calibration settings.
    pub fn new(
        calib_samples: usize,
        calib_seed: u64,
        curves_dir: Option<std::path::PathBuf>,
    ) -> PlanStore {
        PlanStore {
            calib_samples,
            calib_seed,
            curves_dir,
            curves: HashMap::new(),
            plans: HashMap::new(),
        }
    }

    fn default_k_max(family: &str) -> usize {
        // paper §3.1: k ≤ 3 for DiT-XL / Stable Audio, k ≤ 5 for OpenSora
        if family == "video" {
            5
        } else {
            3
        }
    }

    fn default_calib_cfg(family: &str) -> f32 {
        // DiT calibrates unconditionally; OpenSora / Stable Audio
        // calibrate conditionally (paper §3.1)
        if family == "image" {
            1.0
        } else {
            7.0
        }
    }

    /// Whether calibration curves for (family, solver, steps) are
    /// already available — in memory, or pre-computed on disk under
    /// `curves_dir` — i.e. a curve-needing request for this
    /// configuration would resolve without paying a calibration. The
    /// batcher uses this (via `try_lock`, never blocking behind an
    /// in-flight calibration) to pick the work-queue lane.
    pub fn has_curves(
        &self,
        family: &str,
        solver: crate::solvers::SolverKind,
        steps: usize,
    ) -> bool {
        if self
            .curves
            .contains_key(&(family.to_string(), solver.name().to_string(), steps))
        {
            return true;
        }
        // disk-cached curves load without calibrating (see `curves()`),
        // so they make the key just as hot as in-memory ones
        match &self.curves_dir {
            Some(dir) => dir
                .join(format!("{family}_{}_{steps}.json", solver.name()))
                .exists(),
            None => false,
        }
    }

    /// Get (calibrating if needed) the error curves for a configuration.
    pub fn curves(
        &mut self,
        engine: &Engine,
        metrics: Option<&Metrics>,
        family: &str,
        solver: crate::solvers::SolverKind,
        steps: usize,
    ) -> Result<&ErrorCurves> {
        let key = (family.to_string(), solver.name().to_string(), steps);
        if !self.curves.contains_key(&key) {
            // try the on-disk cache first
            let mut loaded = None;
            if let Some(dir) = &self.curves_dir {
                let p = dir.join(format!("{family}_{}_{steps}.json", solver.name()));
                if let Ok(text) = std::fs::read_to_string(&p) {
                    loaded = ErrorCurves::parse_str(&text).ok();
                }
            }
            let curves = match loaded {
                Some(c) => c,
                None => {
                    let cc = CalibrationConfig {
                        solver,
                        steps,
                        k_max: Self::default_k_max(family),
                        num_samples: self.calib_samples,
                        cfg_scale: Self::default_calib_cfg(family),
                        seed: self.calib_seed,
                    };
                    if let Some(m) = metrics {
                        Metrics::inc(&m.calibrations);
                    }
                    calibrate(engine, family, &cc)?
                }
            };
            self.curves.insert(key.clone(), curves);
        }
        Ok(self.curves.get(&key).unwrap())
    }

    /// Resolve a static policy to its [`CachePlan`] for one
    /// configuration, building (and calibrating) on first use and
    /// returning the shared cached plan afterwards. Dynamic policies
    /// never reach the store — the executor drives their
    /// [`crate::cache::StepPlanner`] directly, without the lock.
    pub fn plan(
        &mut self,
        engine: &Engine,
        metrics: Option<&Metrics>,
        family: &str,
        solver: crate::solvers::SolverKind,
        steps: usize,
        policy: &super::request::Policy,
    ) -> Result<Arc<CachePlan>> {
        let key = PlanKey {
            family: family.to_string(),
            solver: solver.name().to_string(),
            steps,
            policy: policy.wire().to_string(),
        };
        if let Some(p) = self.plans.get(&key) {
            if let Some(m) = metrics {
                Metrics::inc(&m.plan_cache_hits);
            }
            return Ok(Arc::clone(p));
        }
        let fm = engine.family_manifest(family)?;
        let planner = policy.planner();
        let plan = if planner.needs_curves() {
            let curves = self.curves(engine, metrics, family, solver, steps)?;
            Arc::new(planner.plan(&PlanCtx { family: fm, solver, steps, curves: Some(curves) })?)
        } else {
            Arc::new(planner.plan(&PlanCtx { family: fm, solver, steps, curves: None })?)
        };
        self.plans.insert(key, Arc::clone(&plan));
        // counted only after a successful build + insert, so the
        // counter means "plans actually built and cached"
        if let Some(m) = metrics {
            Metrics::inc(&m.plan_cache_misses);
        }
        Ok(plan)
    }
}

/// Execute one homogeneous batch of requests on the engine.
/// `local_plans` is this replica's private cache for calibration-free
/// static plans (see the resolution comment below) — pass an empty map
/// for one-off execution.
pub fn execute_batch(
    engine: &mut Engine,
    store: &SharedPlanStore,
    local_plans: &mut HashMap<PlanKey, Arc<CachePlan>>,
    metrics: &Metrics,
    batch: Vec<InFlight>,
    supported_batches: &[usize],
) -> Result<()> {
    debug_assert!(!batch.is_empty());
    let exec_start = Instant::now();
    let req0: &Request = &batch[0].request;
    let family = req0.family.clone();
    // cloned (Arc-backed) so the session's PlanRef can borrow the
    // dynamic planner from a local instead of from `batch`, which the
    // step loop must be free to answer and consume
    let policy = req0.policy.clone();
    engine.load_family(&family)?;
    let fm = engine.family_manifest(&family)?.clone();
    let cfg_on = req0.cfg_scale != 1.0;

    // pad to the nearest AOT-compiled batch size
    let n = batch.len();
    let target = (n..)
        .find(|&b| {
            let eff = if cfg_on { 2 * b } else { b };
            supported_batches.contains(&eff)
        })
        .ok_or_else(|| crate::err!("no supported batch ≥ {n}"))?;
    Metrics::add(&metrics.padded_slots, (target - n) as u64);

    // conditioning: concat + pad
    let mut cond = batch[0].request.cond.clone();
    for it in &batch[1..] {
        cond = cond.cat(&it.request.cond);
    }
    let cond = cond.pad_to(target, fm.cond_len);

    // per-request init latents from their own seeds
    let mut lat_shape = vec![1usize];
    lat_shape.extend(&fm.latent_shape);
    let latents: Vec<Tensor> = batch
        .iter()
        .map(|it| SolverRun::init_latent(lat_shape.clone(), &mut Rng::new(it.request.seed)))
        .collect();
    let mut refs: Vec<&Tensor> = latents.iter().collect();
    let pad_extra = target - n;
    for _ in 0..pad_extra {
        refs.push(latents.last().unwrap());
    }
    let x_init = Tensor::cat0(&refs);

    // Calibration-free policies are pure functions of the manifest
    // geometry — resolve them WITHOUT the shared store lock, so a
    // replica calibrating a curve-needing config can never stall them
    // on its siblings. This is what makes the work queue's priority
    // lane a real no-head-of-line-blocking guarantee (ADR-002):
    // overtaking in the queue would be worthless if the batch then
    // parked on the store mutex a calibration holds. Only policies
    // whose planner needs curves take the lock, and calibration
    // deliberately runs under it: that is what makes "calibrate once
    // per config" hold across the pool. (Residual, documented in
    // ADR-002: an already-calibrated smooth key can still wait behind
    // an in-flight calibration of a *different* smooth key.) Dynamic
    // policies carry no plan at all — their StepPlanner decides inside
    // the generate loop from runtime observations.
    let gen_cfg = GenConfig::new(&family, req0.solver, req0.steps)
        .with_cfg(req0.cfg_scale)
        .with_seed(req0.seed)
        .with_compute(req0.compute);
    let (solver, steps) = (req0.solver, req0.steps);
    let planner = policy.planner();
    let held_plan;
    let plan = if let Some(sp) = planner.dynamic() {
        PlanRef::Planner(sp)
    } else if !planner.needs_curves() {
        // cached per *replica* (lock-free), built at most once per
        // configuration — repeated traffic pays one flat-map lookup,
        // not a rebuild + validate per batch
        let key = PlanKey {
            family: family.clone(),
            solver: solver.name().to_string(),
            steps,
            policy: policy.wire().to_string(),
        };
        held_plan = match local_plans.get(&key) {
            Some(p) => Arc::clone(p),
            None => {
                let p = Arc::new(planner.plan(&PlanCtx {
                    family: &fm,
                    solver,
                    steps,
                    curves: None,
                })?);
                local_plans.insert(key, Arc::clone(&p));
                p
            }
        };
        PlanRef::Plan(&held_plan)
    } else {
        held_plan =
            lock_store(store).plan(engine, Some(metrics), &family, solver, steps, &policy)?;
        PlanRef::Plan(&held_plan)
    };

    // Step-driven execution over a GenSession: between every solver
    // step the executor checks cancellation and reject-late deadlines
    // (abandoning the whole batch once every member is dead — a live
    // sibling's work always completes), emits per-step progress events
    // to streaming requests, and accounts per-step latency. This is the
    // cooperative-cancellation seam: no locks are held across a check,
    // so aborting is always safe, including while another replica holds
    // the plan store inside a calibration.
    let queue_at = exec_start;
    let mut session = GenSession::from_latent(engine, &gen_cfg, &cond, x_init, plan)?;
    let steps_total = session.total_steps();
    while !session.is_done() {
        if batch.iter().all(|it| it.dead_on_arrival()) {
            for it in batch {
                reply_dead(metrics, it);
            }
            return Ok(());
        }
        let t_step = Instant::now();
        let ev = session.step()?;
        metrics.step_latency.observe(t_step.elapsed().as_secs_f64());
        Metrics::inc(&metrics.steps_executed);
        let elapsed_s = exec_start.elapsed().as_secs_f64();
        for it in &batch {
            if it.cancel.is_cancelled() {
                continue;
            }
            if let Some(tx) = &it.progress {
                let _ = tx.send(Progress {
                    id: it.request.id,
                    step: ev.step,
                    steps: steps_total,
                    computes: ev.computes,
                    reuses: ev.reuses,
                    drift: ev.max_drift,
                    elapsed_s,
                });
            }
        }
    }
    let out = session.finish();
    let exec_seconds = exec_start.elapsed().as_secs_f64();

    Metrics::inc(&metrics.batches_executed);
    Metrics::add(&metrics.branch_computes, out.stats.branch_computes as u64);
    Metrics::add(&metrics.branch_reuses, out.stats.branch_reuses as u64);
    metrics.exec_latency.observe(exec_seconds);

    let now = Instant::now();
    for (i, it) in batch.into_iter().enumerate() {
        // cancelled / reject-late-expired while siblings kept the batch
        // alive: the result is discarded for this request only
        if it.cancel.is_cancelled()
            || it
                .deadline
                .is_some_and(|d| d.policy == DeadlinePolicy::RejectLate && now >= d.at)
        {
            reply_dead(metrics, it);
            continue;
        }
        let deadline_missed = it.deadline.is_some_and(|d| now >= d.at);
        if deadline_missed {
            // best-effort deadline: deliver the late result, count it
            Metrics::inc(&metrics.deadline_missed);
        }
        let queue_seconds = queue_at.duration_since(it.submitted).as_secs_f64();
        let total = it.submitted.elapsed().as_secs_f64();
        metrics.queue_latency.observe(queue_seconds);
        metrics.e2e_latency.observe(total);
        Metrics::inc(&metrics.requests_completed);
        let resp = Response {
            id: it.request.id,
            latent: out.latent.sample(i),
            batch_size: target,
            steps_completed: out.stats.steps,
            deadline_missed,
            queue_seconds,
            exec_seconds,
            total_seconds: total,
            gen_stats: out.stats.clone(),
        };
        let _ = it.reply.send(Ok(resp));
    }
    Ok(())
}

/// One executor replica's loop: opens its own engine on this thread,
/// then pulls batches from the shared work queue until the queue is
/// closed and drained — the pull model means a replica busy with a
/// long calibration simply stops pulling, and can never
/// head-of-line-block batches a sibling could serve. `worker` is the
/// replica index (used for log prefixes); `live` counts replicas whose
/// engine opened, so the *last* replica to fail startup stays behind
/// to fail queued requests instead of letting them hang.
pub fn run_executor(
    worker: usize,
    config: ExecutorConfig,
    supported_batches: Vec<usize>,
    queue: Arc<WorkQueue>,
    live: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
    store: SharedPlanStore,
) {
    let mut engine = match Engine::open(config.artifacts_dir.clone()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("executor[{worker}]: failed to open engine: {e:#}");
            // With a shared queue a broken replica must NOT keep
            // pulling (it would race healthy siblings for work just to
            // fail it). Leave the pool — unless every replica is gone,
            // in which case drain-and-fail so requests error instead of
            // hanging until shutdown.
            if live.fetch_sub(1, Ordering::SeqCst) == 1 {
                while let Some(q) = queue.pop() {
                    Metrics::set(&metrics.queue_depth, queue.len() as u64);
                    for it in q.batch {
                        Metrics::inc(&metrics.requests_failed);
                        let _ = it.reply.send(Err(crate::err!("engine unavailable")));
                    }
                }
            }
            return;
        }
    };
    for fam in &config.preload {
        if let Err(e) = engine.load_family(fam) {
            eprintln!("executor[{worker}]: preload {fam}: {e:#}");
        }
    }

    // replica-local cache of calibration-free static plans: lock-free
    // by construction (never shared), so ADR-002's no-head-of-line
    // guarantee is untouched while repeated traffic stops rebuilding
    // identical plans per batch
    let mut local_plans: HashMap<PlanKey, Arc<CachePlan>> = HashMap::new();

    while let Some(q) = queue.pop() {
        Metrics::set(&metrics.queue_depth, queue.len() as u64);
        metrics.queue_wait.observe(q.enqueued.elapsed().as_secs_f64());
        // shed requests that died while queued (cancelled, or past a
        // reject-late deadline) before any work happens — they never
        // reach the engine, and a fully dead batch is skipped outright
        let (batch, dead): (Vec<_>, Vec<_>) =
            q.batch.into_iter().partition(|it| !it.dead_on_arrival());
        for it in dead {
            reply_dead(&metrics, it);
        }
        if batch.is_empty() {
            continue;
        }
        // keep reply handles in case of failure
        let ids: Vec<u64> = batch.iter().map(|b| b.request.id).collect();
        let replies: Vec<_> = batch.iter().map(|b| b.reply.clone()).collect();
        if let Err(e) = execute_batch(
            &mut engine,
            &store,
            &mut local_plans,
            &metrics,
            batch,
            &supported_batches,
        ) {
            eprintln!("executor[{worker}]: batch {ids:?} failed: {e:#}");
            for r in replies {
                Metrics::inc(&metrics.requests_failed);
                let _ = r.send(Err(crate::err!("batch execution failed: {e}")));
            }
        }
    }
}
