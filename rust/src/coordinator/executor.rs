//! The executor replicas: each executor thread owns its *own* engine
//! (and thus its own backend instance — PJRT handles are thread-bound,
//! so that backend runs exactly one replica; the reference backend
//! replicates freely), pulls work from the coordinator's shared
//! [`WorkQueue`] whenever it goes idle, resolves caching policies to
//! concrete [`CachePlan`]s through the pool-shared [`PlanStore`]
//! (calibrating on demand, exactly once per configuration across all
//! replicas) — or drives a [`crate::cache::StepPlanner`] at runtime for
//! dynamic policies — and runs batched generations.
//!
//! Preemption (docs/adr/007): while driving a **batch-class**
//! generation the executor checks, after every solver step, whether
//! fresh interactive work is waiting
//! ([`WorkQueue::should_preempt`]). If so it snapshots the session
//! ([`GenSession::snapshot`]) and parks it back into the queue; any
//! replica later resumes it ([`resume_parked`]) bitwise-identically to
//! an uninterrupted run. The check runs *after* a step, so a resumed
//! session always makes ≥ 1 step of progress per scheduling slot —
//! combined with the queue's aging rule this bounds every parked job's
//! completion even under a sustained interactive flood.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::util::error::Result;

use super::cancel::{reply_dead, DeadlinePolicy, Progress};
use super::metrics::Metrics;
use super::queue::{ParkedSession, WorkItem, WorkQueue};
use super::request::{InFlight, Policy, PriorityClass, Request, Response};
use crate::cache::plan::{CachePlan, PlanCtx, PlanRef};
use crate::cache::{calibrate, CalibrationConfig, ErrorCurves};
use crate::model::{Engine, FamilyManifest};
use crate::obs::{self, BatchTrace, Outcome};
use crate::pipeline::{GenConfig, GenSession};
use crate::solvers::{SolverKind, SolverRun};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-replica configuration (cloned into every executor thread).
#[derive(Clone)]
pub struct ExecutorConfig {
    /// artifact directory the replica's engine opens (manifest,
    /// weights, executables — or nothing, for the builtin geometry).
    pub artifacts_dir: std::path::PathBuf,
    /// families to preload at startup (lazy for the rest).
    pub preload: Vec<String>,
    /// calibration samples for on-demand SmoothCache calibration
    /// (paper: 10; servers may trade a few for startup time).
    pub calib_samples: usize,
    /// seed for on-demand calibration passes.
    pub calib_seed: u64,
    /// optional directory with pre-computed calibration curves
    /// (artifacts/calibration/{family}_{solver}_{steps}.json).
    pub curves_dir: Option<std::path::PathBuf>,
}

/// One [`PlanStore`] shared by every executor replica. Calibration is
/// expensive, so the first replica to need a (family, solver, steps)
/// configuration calibrates while same-key followers block on that
/// key's slot and then read the cached curves — the "calibrate once
/// per config" serving contract holds at any pool size. Since the
/// per-key slot rework (this PR, closing the ADR-002 residual),
/// calibrations of *different* keys no longer serialize each other:
/// the store-wide lock is only ever held for map lookups.
pub type SharedPlanStore = Arc<Mutex<PlanStore>>;

/// Lock the shared store, recovering from a replica that panicked while
/// holding it (the store's maps are always left consistent: entries are
/// inserted fully-formed).
pub fn lock_store(store: &SharedPlanStore) -> MutexGuard<'_, PlanStore> {
    store.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Cache key for one resolved plan: the full configuration a
/// [`CachePlan`] is specific to.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// model family the plan was built for.
    pub family: String,
    /// solver name (calibrated plans are trajectory-specific).
    pub solver: String,
    /// sampling steps the plan spans.
    pub steps: usize,
    /// canonical policy wire string.
    pub policy: String,
}

/// One calibration key's curve cell: `None` until the first
/// load-or-calibrate fills it. The per-key `Mutex` is the whole point
/// — a replica calibrating key A holds A's slot, not the store, so a
/// request for already-calibrated key B resolves concurrently.
type CurveSlot = Arc<Mutex<Option<Arc<ErrorCurves>>>>;

fn curve_key(family: &str, solver: SolverKind, steps: usize) -> (String, String, usize) {
    (family.to_string(), solver.name().to_string(), steps)
}

/// Load pre-computed curves from `curves_dir`, or run a calibration
/// pass. Pure with respect to the store — callers hold (at most) the
/// relevant [`CurveSlot`] while invoking this, never the store lock.
fn load_or_calibrate(
    engine: &Engine,
    metrics: Option<&Metrics>,
    family: &str,
    solver: SolverKind,
    steps: usize,
    calib_samples: usize,
    calib_seed: u64,
    curves_dir: &Option<std::path::PathBuf>,
) -> Result<ErrorCurves> {
    if let Some(dir) = curves_dir {
        let p = dir.join(format!("{family}_{}_{steps}.json", solver.name()));
        if let Ok(text) = std::fs::read_to_string(&p) {
            if let Ok(c) = ErrorCurves::parse_str(&text) {
                return Ok(c);
            }
        }
    }
    let cc = CalibrationConfig {
        solver,
        steps,
        k_max: PlanStore::default_k_max(family),
        num_samples: calib_samples,
        cfg_scale: PlanStore::default_calib_cfg(family),
        seed: calib_seed,
    };
    if let Some(m) = metrics {
        Metrics::inc(&m.calibrations);
    }
    calibrate(engine, family, &cc)
}

/// Caches calibration curves and resolved [`CachePlan`]s across
/// requests: one `PlanKey → Arc<CachePlan>` map for every policy shape,
/// and one [`CurveSlot`] per calibration key. Invariant: entries are
/// only ever inserted fully-formed, so any observable state is
/// consistent even after a panic mid-request.
pub struct PlanStore {
    /// calibration samples for on-demand calibration (see
    /// [`ExecutorConfig::calib_samples`]).
    pub calib_samples: usize,
    /// seed for on-demand calibration passes.
    pub calib_seed: u64,
    /// optional directory of pre-computed calibration curves, checked
    /// before calibrating.
    pub curves_dir: Option<std::path::PathBuf>,
    curves: HashMap<(String, String, usize), CurveSlot>,
    plans: HashMap<PlanKey, Arc<CachePlan>>,
}

impl PlanStore {
    /// An empty store with the given calibration settings.
    pub fn new(
        calib_samples: usize,
        calib_seed: u64,
        curves_dir: Option<std::path::PathBuf>,
    ) -> PlanStore {
        PlanStore {
            calib_samples,
            calib_seed,
            curves_dir,
            curves: HashMap::new(),
            plans: HashMap::new(),
        }
    }

    fn default_k_max(family: &str) -> usize {
        // paper §3.1: k ≤ 3 for DiT-XL / Stable Audio, k ≤ 5 for OpenSora
        if family == "video" {
            5
        } else {
            3
        }
    }

    fn default_calib_cfg(family: &str) -> f32 {
        // DiT calibrates unconditionally; OpenSora / Stable Audio
        // calibrate conditionally (paper §3.1)
        if family == "image" {
            1.0
        } else {
            7.0
        }
    }

    /// Whether calibration curves for (family, solver, steps) are
    /// already available — in memory, or pre-computed on disk under
    /// `curves_dir` — i.e. a curve-needing request for this
    /// configuration would resolve without paying a calibration. The
    /// batcher uses this (via `try_lock` on the store, and `try_lock`
    /// on the key's slot here — never blocking behind an in-flight
    /// calibration of *any* key) to pick the work-queue lane; a slot
    /// mid-calibration conservatively reads as cold.
    pub fn has_curves(
        &self,
        family: &str,
        solver: crate::solvers::SolverKind,
        steps: usize,
    ) -> bool {
        if let Some(slot) = self.curves.get(&curve_key(family, solver, steps)) {
            if let Ok(cell) = slot.try_lock() {
                if cell.is_some() {
                    return true;
                }
            }
            // calibration in flight (WouldBlock) or slot still empty:
            // fall through to the disk check
        }
        // disk-cached curves load without calibrating (see `curves()`),
        // so they make the key just as hot as in-memory ones
        match &self.curves_dir {
            Some(dir) => dir
                .join(format!("{family}_{}_{steps}.json", solver.name()))
                .exists(),
            None => false,
        }
    }

    /// Get (calibrating if needed) the error curves for a configuration.
    pub fn curves(
        &mut self,
        engine: &Engine,
        metrics: Option<&Metrics>,
        family: &str,
        solver: crate::solvers::SolverKind,
        steps: usize,
    ) -> Result<Arc<ErrorCurves>> {
        let slot = Arc::clone(
            self.curves
                .entry(curve_key(family, solver, steps))
                .or_default(),
        );
        let mut cell = slot.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(c) = &*cell {
            return Ok(Arc::clone(c));
        }
        let c = Arc::new(load_or_calibrate(
            engine,
            metrics,
            family,
            solver,
            steps,
            self.calib_samples,
            self.calib_seed,
            &self.curves_dir,
        )?);
        *cell = Some(Arc::clone(&c));
        Ok(c)
    }

    /// Resolve a static policy to its [`CachePlan`] for one
    /// configuration, building (and calibrating) on first use and
    /// returning the shared cached plan afterwards. Owned-store
    /// convenience (CLI, tests, benches); the serving path uses
    /// [`plan_shared`], which blocks same-key waiters only. Dynamic
    /// policies never reach the store — the executor drives their
    /// [`crate::cache::StepPlanner`] directly, without the lock.
    pub fn plan(
        &mut self,
        engine: &Engine,
        metrics: Option<&Metrics>,
        family: &str,
        solver: crate::solvers::SolverKind,
        steps: usize,
        policy: &super::request::Policy,
    ) -> Result<Arc<CachePlan>> {
        let key = PlanKey {
            family: family.to_string(),
            solver: solver.name().to_string(),
            steps,
            policy: policy.wire().to_string(),
        };
        if let Some(p) = self.plans.get(&key) {
            if let Some(m) = metrics {
                Metrics::inc(&m.plan_cache_hits);
            }
            return Ok(Arc::clone(p));
        }
        let planner = policy.planner();
        let held_curves = if planner.needs_curves() {
            Some(self.curves(engine, metrics, family, solver, steps)?)
        } else {
            None
        };
        let fm = engine.family_manifest(family)?;
        let plan = Arc::new(planner.plan(&PlanCtx {
            family: fm,
            solver,
            steps,
            curves: held_curves.as_deref(),
        })?);
        self.plans.insert(key, Arc::clone(&plan));
        // counted only after a successful build + insert, so the
        // counter means "plans actually built and cached"
        if let Some(m) = metrics {
            Metrics::inc(&m.plan_cache_misses);
        }
        Ok(plan)
    }
}

/// Resolve a curve-needing policy through the shared store with
/// **per-key** calibration locking (this PR's ADR-002-residual fix):
/// the store-wide mutex is held only for map lookups; a cold key's
/// calibration runs under that key's [`CurveSlot`] alone, so an
/// already-calibrated key — or a different cold key — resolves
/// concurrently instead of queueing behind a foreign calibration.
/// Pinned by `warm_key_resolves_while_foreign_calibration_is_in_flight`
/// in `tests/coordinator_props.rs`.
pub fn plan_shared(
    store: &SharedPlanStore,
    engine: &Engine,
    metrics: Option<&Metrics>,
    family: &str,
    solver: SolverKind,
    steps: usize,
    policy: &Policy,
) -> Result<Arc<CachePlan>> {
    let key = PlanKey {
        family: family.to_string(),
        solver: solver.name().to_string(),
        steps,
        policy: policy.wire().to_string(),
    };
    // brief store lock: plan fast path + curve-slot acquisition
    let (slot, calib_samples, calib_seed, curves_dir) = {
        let mut st = lock_store(store);
        if let Some(p) = st.plans.get(&key) {
            if let Some(m) = metrics {
                Metrics::inc(&m.plan_cache_hits);
            }
            return Ok(Arc::clone(p));
        }
        let slot = Arc::clone(st.curves.entry(curve_key(family, solver, steps)).or_default());
        (slot, st.calib_samples, st.calib_seed, st.curves_dir.clone())
    };
    let planner = policy.planner();
    let held_curves = if planner.needs_curves() {
        // only same-key waiters block here; a foreign calibration holds
        // a different slot
        let mut cell = slot.lock().unwrap_or_else(|p| p.into_inner());
        let c = match &*cell {
            Some(c) => Arc::clone(c),
            None => {
                let c = Arc::new(load_or_calibrate(
                    engine,
                    metrics,
                    family,
                    solver,
                    steps,
                    calib_samples,
                    calib_seed,
                    &curves_dir,
                )?);
                *cell = Some(Arc::clone(&c));
                c
            }
        };
        Some(c)
    } else {
        None
    };
    let fm = engine.family_manifest(family)?;
    let plan = Arc::new(planner.plan(&PlanCtx {
        family: fm,
        solver,
        steps,
        curves: held_curves.as_deref(),
    })?);
    // publish under a second brief store lock; a racing same-key
    // builder may have won — keep the first insert so every replica
    // shares one Arc
    let mut st = lock_store(store);
    let shared = Arc::clone(st.plans.entry(key).or_insert_with(|| Arc::clone(&plan)));
    if let Some(m) = metrics {
        Metrics::inc(&m.plan_cache_misses);
    }
    Ok(shared)
}

/// Resolve the (static) plan for one request shape, or `None` for a
/// dynamic policy (the caller borrows the policy's
/// [`crate::cache::StepPlanner`] instead). Calibration-free policies
/// are pure functions of the manifest geometry — resolved from the
/// replica-local `local_plans` cache WITHOUT any shared lock, so a
/// replica calibrating a curve-needing config can never stall them on
/// its siblings (this is what makes the work queue's priority lane a
/// real no-head-of-line-blocking guarantee, ADR-002: overtaking in the
/// queue would be worthless if the batch then parked on a store mutex).
/// Curve-needing policies go through [`plan_shared`]. Deterministic for
/// a fixed store state — a parked session resumed on any replica
/// re-resolves to an identical plan.
#[allow(clippy::too_many_arguments)]
fn resolve_plan(
    engine: &Engine,
    store: &SharedPlanStore,
    local_plans: &mut HashMap<PlanKey, Arc<CachePlan>>,
    metrics: &Metrics,
    fm: &FamilyManifest,
    family: &str,
    solver: SolverKind,
    steps: usize,
    policy: &Policy,
) -> Result<Option<Arc<CachePlan>>> {
    let planner = policy.planner();
    if planner.dynamic().is_some() {
        return Ok(None);
    }
    if !planner.needs_curves() {
        // cached per *replica* (lock-free), built at most once per
        // configuration — repeated traffic pays one flat-map lookup,
        // not a rebuild + validate per batch
        let key = PlanKey {
            family: family.to_string(),
            solver: solver.name().to_string(),
            steps,
            policy: policy.wire().to_string(),
        };
        if let Some(p) = local_plans.get(&key) {
            return Ok(Some(Arc::clone(p)));
        }
        let p = Arc::new(planner.plan(&PlanCtx { family: fm, solver, steps, curves: None })?);
        local_plans.insert(key, Arc::clone(&p));
        return Ok(Some(p));
    }
    Ok(Some(plan_shared(
        store,
        engine,
        Some(metrics),
        family,
        solver,
        steps,
        policy,
    )?))
}

/// Drive a session to completion — or to a preemption point. Shared by
/// the fresh-batch path ([`execute_batch`]) and the resume path
/// ([`resume_parked`]); `members` carries `(latent row, request)` so a
/// member cancelled across park/resume cycles never shifts its
/// siblings' rows. `exec_accum` / `first_exec` carry timing across
/// segments: `exec_seconds` on the response is total model time over
/// all segments, `queue_seconds` stays submit → *first* execution
/// start.
#[allow(clippy::too_many_arguments)]
fn drive(
    mut session: GenSession<'_>,
    queue: &WorkQueue,
    metrics: &Metrics,
    mut members: Vec<(usize, InFlight)>,
    target: usize,
    exec_accum: f64,
    first_exec: Instant,
    seg_start: Instant,
) -> Result<()> {
    debug_assert!(!members.is_empty());
    let steps_total = session.total_steps();
    let class = members[0].1.request.priority;
    // span fan-out to every traced member of the batch; costs nothing
    // when no member is traced
    let bt = BatchTrace::new(members.iter().map(|(_, it)| &it.trace));
    while !session.is_done() {
        // Between every solver step the executor checks cancellation
        // and reject-late deadlines (abandoning the whole batch once
        // every member is dead — a live sibling's work always
        // completes), emits per-step progress events to streaming
        // requests, and accounts per-step latency. No locks are held
        // across a check, so aborting is always safe, including while
        // another replica holds a calibration slot.
        if members.iter().all(|(_, it)| it.dead_on_arrival()) {
            for (_, it) in members {
                reply_dead(metrics, it);
            }
            return Ok(());
        }
        let t_step = Instant::now();
        let t0 = bt.begin();
        // the fine scope stages per-(step, site) decision events on
        // this thread and flushes them to the traced members after the
        // step; below TraceLevel::Fine it is exactly `session.step()`
        let ev = obs::with_fine_scope(&bt, || session.step())?;
        bt.span_from(
            "step",
            t0,
            ev.step as u64,
            ev.computes as u64,
            ev.reuses as u64,
            ev.max_drift.unwrap_or(f64::NAN),
        );
        metrics.step_latency.observe(t_step.elapsed().as_secs_f64());
        Metrics::inc(&metrics.steps_executed);
        let elapsed_s = exec_accum + seg_start.elapsed().as_secs_f64();
        for (_, it) in &members {
            if it.cancel.is_cancelled() {
                continue;
            }
            if let Some(tx) = &it.progress {
                let _ = tx.send(Progress {
                    id: it.request.id,
                    step: ev.step,
                    steps: steps_total,
                    computes: ev.computes,
                    reuses: ev.reuses,
                    drift: ev.max_drift,
                    elapsed_s,
                });
            }
        }
        // Preemption point (docs/adr/007): checked *after* the step so
        // every scheduling slot makes ≥ 1 step of progress — a parked
        // job therefore finishes in at most `steps` resumes no matter
        // how hostile the interactive arrival pattern is.
        if class == PriorityClass::Batch && !session.is_done() && queue.should_preempt(class) {
            bt.event("park", (ev.step + 1) as u64, 0, 0, f64::NAN);
            let state = session.snapshot();
            Metrics::inc(&metrics.preemptions);
            queue.push_parked(ParkedSession {
                members,
                state,
                target,
                class,
                exec_seconds: exec_accum + seg_start.elapsed().as_secs_f64(),
                first_exec,
                parked_at: Instant::now(),
            });
            let parked = queue.parked_len() as u64;
            Metrics::set(&metrics.parked_sessions, parked);
            Metrics::raise(&metrics.parked_peak, parked);
            return Ok(());
        }
    }
    let out = session.finish();
    // out.stats spans every segment of the trajectory (SessionState
    // carries the counters across parks), so these totals are counted
    // exactly once, at completion
    let exec_seconds = exec_accum + seg_start.elapsed().as_secs_f64();
    Metrics::inc(&metrics.batches_executed);
    Metrics::add(&metrics.branch_computes, out.stats.branch_computes as u64);
    Metrics::add(&metrics.branch_reuses, out.stats.branch_reuses as u64);
    metrics.exec_latency.observe(exec_seconds);

    let now = Instant::now();
    for (row, it) in members {
        // cancelled / reject-late-expired while siblings kept the batch
        // alive: the result is discarded for this request only
        if it.cancel.is_cancelled()
            || it
                .deadline
                .is_some_and(|d| d.policy == DeadlinePolicy::RejectLate && now >= d.at)
        {
            reply_dead(metrics, it);
            continue;
        }
        let deadline_missed = it.deadline.is_some_and(|d| now >= d.at);
        if deadline_missed {
            // best-effort deadline: deliver the late result, count it
            Metrics::inc(&metrics.deadline_missed);
        }
        let queue_seconds = first_exec.duration_since(it.submitted).as_secs_f64();
        let total = it.submitted.elapsed().as_secs_f64();
        metrics.queue_latency.observe(queue_seconds);
        metrics.e2e_latency.observe(total);
        match it.request.priority {
            PriorityClass::Interactive => metrics.e2e_interactive.observe(total),
            PriorityClass::Batch => metrics.e2e_batch.observe(total),
        }
        Metrics::inc(&metrics.requests_completed);
        let resp = Response {
            id: it.request.id,
            latent: out.latent.sample(row),
            batch_size: target,
            steps_completed: out.stats.steps,
            deadline_missed,
            queue_seconds,
            exec_seconds,
            total_seconds: total,
            gen_stats: out.stats.clone(),
        };
        // seal the flight entry before the reply leaves (a client can
        // `dump` the moment it sees the response); a late best-effort
        // result is pinned — that is the timeline an operator debugging
        // tail latency wants
        it.trace
            .finish(if deadline_missed { Outcome::DeadlineMissed } else { Outcome::Ok });
        let _ = it.reply.send(Ok(resp));
    }
    Ok(())
}

/// Execute one homogeneous batch of requests on the engine (possibly
/// parking it at a preemption point — see [`drive`]). `local_plans` is
/// this replica's private cache for calibration-free static plans —
/// pass an empty map for one-off execution.
pub fn execute_batch(
    engine: &mut Engine,
    store: &SharedPlanStore,
    local_plans: &mut HashMap<PlanKey, Arc<CachePlan>>,
    metrics: &Metrics,
    queue: &WorkQueue,
    batch: Vec<InFlight>,
    supported_batches: &[usize],
) -> Result<()> {
    debug_assert!(!batch.is_empty());
    let exec_start = Instant::now();
    let req0: &Request = &batch[0].request;
    let family = req0.family.clone();
    // cloned (Arc-backed) so the session's PlanRef can borrow the
    // dynamic planner from a local instead of from `batch`, which the
    // step loop must be free to answer and consume
    let policy = req0.policy.clone();
    let (solver, steps) = (req0.solver, req0.steps);
    engine.load_family(&family)?;
    let fm = engine.family_manifest(&family)?.clone();
    let cfg_on = req0.cfg_scale != 1.0;

    // pad to the nearest AOT-compiled batch size
    let n = batch.len();
    let target = (n..)
        .find(|&b| {
            let eff = if cfg_on { 2 * b } else { b };
            supported_batches.contains(&eff)
        })
        .ok_or_else(|| crate::err!("no supported batch ≥ {n}"))?;
    Metrics::add(&metrics.padded_slots, (target - n) as u64);
    let bt = BatchTrace::new(batch.iter().map(|it| &it.trace));
    bt.event("batch", n as u64, (target - n) as u64, 0, f64::NAN);

    // conditioning: concat + pad
    let mut cond = batch[0].request.cond.clone();
    for it in &batch[1..] {
        cond = cond.cat(&it.request.cond);
    }
    let cond = cond.pad_to(target, fm.cond_len);

    // per-request init latents from their own seeds
    let mut lat_shape = vec![1usize];
    lat_shape.extend(&fm.latent_shape);
    let latents: Vec<Tensor> = batch
        .iter()
        .map(|it| SolverRun::init_latent(lat_shape.clone(), &mut Rng::new(it.request.seed)))
        .collect();
    let mut refs: Vec<&Tensor> = latents.iter().collect();
    let pad_extra = target - n;
    for _ in 0..pad_extra {
        refs.push(latents.last().unwrap());
    }
    let x_init = Tensor::cat0(&refs);

    let gen_cfg = GenConfig::new(&family, solver, steps)
        .with_cfg(req0.cfg_scale)
        .with_seed(req0.seed)
        .with_compute(req0.compute);
    // covers policy resolution end to end: a cold curve-needing key
    // pays its calibration inside this span, a warm key microseconds
    let t_cal = bt.begin();
    let held_plan = resolve_plan(
        engine,
        store,
        local_plans,
        metrics,
        &fm,
        &family,
        solver,
        steps,
        &policy,
    )?;
    bt.span_from("calibrate", t_cal, 0, 0, 0, f64::NAN);
    let planner = policy.planner();
    let plan = match &held_plan {
        Some(p) => PlanRef::Plan(p.as_ref()),
        None => PlanRef::Planner(
            planner
                .dynamic()
                .ok_or_else(|| crate::err!("policy resolved to neither plan nor planner"))?,
        ),
    };

    let session = GenSession::from_latent(engine, &gen_cfg, &cond, x_init, plan)?;
    let members: Vec<(usize, InFlight)> = batch.into_iter().enumerate().collect();
    drive(session, queue, metrics, members, target, 0.0, exec_start, exec_start)
}

/// Resume a parked session on this replica: shed members that died
/// while parked, re-resolve the plan (deterministic, so the trajectory
/// stays bitwise-identical to an uninterrupted run — pinned by
/// `tests/session_parity.rs` and the preemption-parity props), and
/// drive from the snapshot.
pub fn resume_parked(
    engine: &mut Engine,
    store: &SharedPlanStore,
    local_plans: &mut HashMap<PlanKey, Arc<CachePlan>>,
    metrics: &Metrics,
    queue: &WorkQueue,
    parked: ParkedSession,
) -> Result<()> {
    let seg_start = Instant::now();
    let parked_s = parked.parked_at.elapsed().as_secs_f64();
    metrics.resume_latency.observe(parked_s);
    let ParkedSession { members, state, target, exec_seconds, first_exec, .. } = parked;
    let (live, dead): (Vec<_>, Vec<_>) =
        members.into_iter().partition(|(_, it)| !it.dead_on_arrival());
    for (_, it) in dead {
        reply_dead(metrics, it);
    }
    if live.is_empty() {
        // every member died while parked: the partial work is discarded
        return Ok(());
    }
    Metrics::inc(&metrics.session_resumes);
    BatchTrace::new(live.iter().map(|(_, it)| &it.trace)).event(
        "resume",
        state.step() as u64,
        0,
        0,
        parked_s,
    );
    let req0: &Request = &live[0].1.request;
    let family = req0.family.clone();
    let policy = req0.policy.clone();
    let (solver, steps) = (req0.solver, req0.steps);
    engine.load_family(&family)?;
    let fm = engine.family_manifest(&family)?.clone();
    let held_plan = resolve_plan(
        engine,
        store,
        local_plans,
        metrics,
        &fm,
        &family,
        solver,
        steps,
        &policy,
    )?;
    let planner = policy.planner();
    let plan = match &held_plan {
        Some(p) => PlanRef::Plan(p.as_ref()),
        None => PlanRef::Planner(
            planner
                .dynamic()
                .ok_or_else(|| crate::err!("policy resolved to neither plan nor planner"))?,
        ),
    };
    let session = GenSession::resume(engine, state, plan)?;
    drive(session, queue, metrics, live, target, exec_seconds, first_exec, seg_start)
}

/// One executor replica's loop: opens its own engine on this thread,
/// then pulls work items from the shared queue until the queue is
/// closed and drained — the pull model means a replica busy with a
/// long calibration simply stops pulling, and can never
/// head-of-line-block batches a sibling could serve. `worker` is the
/// replica index (used for log prefixes); `live` counts replicas whose
/// engine opened, so the *last* replica to fail startup stays behind
/// to fail queued requests instead of letting them hang.
pub fn run_executor(
    worker: usize,
    config: ExecutorConfig,
    supported_batches: Vec<usize>,
    queue: Arc<WorkQueue>,
    live: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
    store: SharedPlanStore,
) {
    let mut engine = match Engine::open(config.artifacts_dir.clone()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("executor[{worker}]: failed to open engine: {e:#}");
            // With a shared queue a broken replica must NOT keep
            // pulling (it would race healthy siblings for work just to
            // fail it). Leave the pool — unless every replica is gone,
            // in which case drain-and-fail so requests error instead of
            // hanging until shutdown (parked sessions included).
            if live.fetch_sub(1, Ordering::SeqCst) == 1 {
                while let Some(item) = queue.pop() {
                    Metrics::set(&metrics.queue_depth, queue.len() as u64);
                    let members: Vec<InFlight> = match item {
                        WorkItem::Fresh(q) => q.batch,
                        WorkItem::Parked(ps) => {
                            ps.members.into_iter().map(|(_, it)| it).collect()
                        }
                    };
                    for it in members {
                        Metrics::inc(&metrics.requests_failed);
                        let msg =
                            crate::err!("engine unavailable{}", it.trace.err_tag());
                        it.trace.finish(Outcome::Failed);
                        let _ = it.reply.send(Err(msg));
                    }
                }
            }
            return;
        }
    };
    for fam in &config.preload {
        if let Err(e) = engine.load_family(fam) {
            eprintln!("executor[{worker}]: preload {fam}: {e:#}");
        }
    }

    // replica-local cache of calibration-free static plans: lock-free
    // by construction (never shared), so ADR-002's no-head-of-line
    // guarantee is untouched while repeated traffic stops rebuilding
    // identical plans per batch
    let mut local_plans: HashMap<PlanKey, Arc<CachePlan>> = HashMap::new();

    while let Some(item) = queue.pop() {
        Metrics::set(&metrics.queue_depth, queue.len() as u64);
        Metrics::set(&metrics.parked_sessions, queue.parked_len() as u64);
        match item {
            WorkItem::Fresh(q) => {
                let qwait = q.enqueued.elapsed().as_secs_f64();
                metrics.queue_wait.observe(qwait);
                match q.class() {
                    PriorityClass::Interactive => metrics.qwait_interactive.observe(qwait),
                    PriorityClass::Batch => metrics.qwait_batch.observe(qwait),
                }
                // shed requests that died while queued (cancelled, or
                // past a reject-late deadline) before any work happens —
                // they never reach the engine, and a fully dead batch is
                // skipped outright
                let (batch, dead): (Vec<_>, Vec<_>) =
                    q.batch.into_iter().partition(|it| !it.dead_on_arrival());
                for it in dead {
                    reply_dead(&metrics, it);
                }
                if batch.is_empty() {
                    continue;
                }
                for it in &batch {
                    it.trace.event("queue_pop", 0, 0, 0, qwait);
                }
                // keep reply handles (and trace handles) in case of failure
                let ids: Vec<u64> = batch.iter().map(|b| b.request.id).collect();
                let replies: Vec<_> =
                    batch.iter().map(|b| (b.reply.clone(), b.trace.clone())).collect();
                if let Err(e) = execute_batch(
                    &mut engine,
                    &store,
                    &mut local_plans,
                    &metrics,
                    &queue,
                    batch,
                    &supported_batches,
                ) {
                    eprintln!("executor[{worker}]: batch {ids:?} failed: {e:#}");
                    for (r, trace) in replies {
                        Metrics::inc(&metrics.requests_failed);
                        let msg = crate::err!(
                            "batch execution failed: {e}{}",
                            trace.err_tag()
                        );
                        trace.finish(Outcome::Failed);
                        let _ = r.send(Err(msg));
                    }
                }
            }
            WorkItem::Parked(ps) => {
                let ids: Vec<u64> = ps.members.iter().map(|(_, it)| it.request.id).collect();
                let replies: Vec<_> = ps
                    .members
                    .iter()
                    .map(|(_, it)| (it.reply.clone(), it.trace.clone()))
                    .collect();
                if let Err(e) = resume_parked(
                    &mut engine,
                    &store,
                    &mut local_plans,
                    &metrics,
                    &queue,
                    ps,
                ) {
                    eprintln!("executor[{worker}]: resume {ids:?} failed: {e:#}");
                    for (r, trace) in replies {
                        Metrics::inc(&metrics.requests_failed);
                        let msg = crate::err!(
                            "batch execution failed: {e}{}",
                            trace.err_tag()
                        );
                        trace.finish(Outcome::Failed);
                        let _ = r.send(Err(msg));
                    }
                }
            }
        }
    }
}
