//! Cooperative cancellation, per-request deadlines and streaming
//! progress for in-flight generation work.
//!
//! A [`CancelToken`] is a cheaply cloneable flag attached to every
//! submitted request. Setting it never interrupts anything directly —
//! each stage of the pipeline checks it at its own safe points: the
//! batcher when flushing a group, the work queue when
//! [`Coordinator::cancel`](super::Coordinator::cancel) purges queued
//! requests (freeing their admission slots immediately), and the
//! executor **between solver steps** while driving a
//! [`crate::pipeline::GenSession`] — so a cancelled request stops
//! within one step without ever poisoning shared state (including the
//! pool-shared plan store a sibling calibration may hold).
//!
//! A [`Deadline`] is an absolute must-finish-by instant with one of two
//! policies: [`DeadlinePolicy::RejectLate`] drops the work (a request
//! whose deadline expired never starts executing, and a late result is
//! answered with a `deadline:` error), while
//! [`DeadlinePolicy::BestEffort`] always delivers the result and only
//! counts/flags the miss.
//!
//! [`Progress`] is the per-step event the executor emits to a
//! request's optional progress channel — the server forwards it as
//! `{"event":"step",…}` lines in streaming mode (docs/protocol.md).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::request::InFlight;

/// Cheaply cloneable cancellation flag shared by everything holding a
/// reference to one request. Setting it is idempotent and never blocks.
#[derive(Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Work already past its last check point
    /// still completes; everything else stops at the next safe point.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// Identity comparison (same underlying flag, not same state) —
    /// distinguishes requests that share a caller-chosen id.
    pub(crate) fn same(&self, other: &CancelToken) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CancelToken({})",
            if self.is_cancelled() { "cancelled" } else { "live" }
        )
    }
}

/// What to do with work that outlives its [`Deadline`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeadlinePolicy {
    /// Run to completion regardless; a late response is still delivered
    /// (flagged `deadline_missed`, counted in the metrics summary).
    /// The default — a missed best-effort deadline costs nothing extra.
    #[default]
    BestEffort,
    /// Shed late work: an expired request never starts executing, and a
    /// result arriving past the deadline is answered with a `deadline:`
    /// error instead of the latent.
    RejectLate,
}

impl DeadlinePolicy {
    /// Parse the wire spelling: `best-effort` or `reject`.
    pub fn parse(s: &str) -> Option<DeadlinePolicy> {
        match s {
            "best-effort" => Some(DeadlinePolicy::BestEffort),
            "reject" => Some(DeadlinePolicy::RejectLate),
            _ => None,
        }
    }

    /// The canonical wire spelling ([`DeadlinePolicy::parse`] inverse).
    pub fn name(&self) -> &'static str {
        match self {
            DeadlinePolicy::BestEffort => "best-effort",
            DeadlinePolicy::RejectLate => "reject",
        }
    }
}

/// An absolute latency budget for one request.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    /// The instant the request must be answered by.
    pub at: Instant,
    /// What happens to work that misses it.
    pub policy: DeadlinePolicy,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration, policy: DeadlinePolicy) -> Deadline {
        Deadline { at: Instant::now() + budget, policy }
    }

    /// Whether the deadline has passed.
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }
}

/// One per-step progress report for a request whose batch is executing
/// (sent on the channel passed in
/// [`SubmitOpts::progress`](super::SubmitOpts)). Decision counters are
/// batch-level: they count sites across the whole executed batch.
#[derive(Clone, Copy, Debug)]
pub struct Progress {
    /// The request this event belongs to.
    pub id: u64,
    /// 0-based solver step that just executed.
    pub step: usize,
    /// Total steps in the trajectory.
    pub steps: usize,
    /// Branch sites computed in this step (whole batch).
    pub computes: usize,
    /// Branch sites that reused a cached delta in this step.
    pub reuses: usize,
    /// Largest per-refresh drift observed this step (dynamic policies).
    pub drift: Option<f64>,
    /// Seconds since this batch started executing — the per-step
    /// progress timestamp streaming clients see.
    pub elapsed_s: f64,
}

/// The coordinator's live id → token registry. Entries are added at
/// submit and removed by the [`CancelRegistration`] drop guard when the
/// request is answered (whatever path answered it), so the map never
/// outgrows the in-flight set.
pub(crate) type CancelMap = Arc<Mutex<HashMap<u64, CancelToken>>>;

pub(crate) fn lock_cancels(map: &CancelMap) -> std::sync::MutexGuard<'_, HashMap<u64, CancelToken>> {
    // the lock only guards map inserts/removals; always consistent
    map.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Drop guard that removes one request's token from the registry when
/// its [`InFlight`] is consumed (answered or dropped on any path).
pub(crate) struct CancelRegistration {
    map: CancelMap,
    id: u64,
    token: CancelToken,
}

impl CancelRegistration {
    /// Insert `token` under `id` and return the guard that removes it.
    pub(crate) fn register(map: &CancelMap, id: u64, token: CancelToken) -> CancelRegistration {
        lock_cancels(map).insert(id, token.clone());
        CancelRegistration { map: Arc::clone(map), id, token }
    }
}

impl Drop for CancelRegistration {
    fn drop(&mut self) {
        let mut m = lock_cancels(&self.map);
        // only remove our own entry — a caller-chosen duplicate id may
        // have overwritten it with a different request's token
        if m.get(&self.id).is_some_and(|t| t.same(&self.token)) {
            m.remove(&self.id);
        }
    }
}

impl std::fmt::Debug for CancelRegistration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CancelRegistration({})", self.id)
    }
}

/// Answer a request that must not (or can no longer) execute: cancelled
/// requests get a `cancelled:` error, reject-late-expired ones a
/// `deadline:` error; the matching metrics counter is bumped. Every
/// call consumes the [`InFlight`], preserving the exactly-one-reply
/// invariant.
pub(crate) fn reply_dead(metrics: &Metrics, it: InFlight) {
    let id = it.request.id;
    let tag = it.trace.err_tag();
    // seal the flight-recorder entry before the reply leaves: a client
    // reacting to the error (e.g. an immediate `dump`) must find it
    if it.cancel.is_cancelled() {
        Metrics::inc(&metrics.requests_cancelled);
        it.trace.finish(crate::obs::Outcome::Cancelled);
        let _ = it
            .reply
            .send(Err(crate::err!("cancelled: request {id} was cancelled{tag}")));
    } else {
        Metrics::inc(&metrics.deadline_missed);
        it.trace.finish(crate::obs::Outcome::DeadlineMissed);
        let _ = it.reply.send(Err(crate::err!(
            "deadline: request {id} exceeded its deadline before completing{tag}"
        )));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_cancels_once_visible_to_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
        t.cancel(); // idempotent
        assert!(t.is_cancelled());
    }

    #[test]
    fn deadline_policy_wire_roundtrip() {
        for p in [DeadlinePolicy::BestEffort, DeadlinePolicy::RejectLate] {
            assert_eq!(DeadlinePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DeadlinePolicy::parse("strict"), None);
    }

    #[test]
    fn deadline_expiry() {
        let d = Deadline::after(Duration::from_secs(3600), DeadlinePolicy::BestEffort);
        assert!(!d.expired());
        let past = Deadline { at: Instant::now(), policy: DeadlinePolicy::RejectLate };
        std::thread::sleep(Duration::from_millis(1));
        assert!(past.expired());
    }

    #[test]
    fn registration_guard_removes_only_its_own_entry() {
        let map: CancelMap = Arc::default();
        let t1 = CancelToken::new();
        let r1 = CancelRegistration::register(&map, 7, t1);
        assert!(lock_cancels(&map).contains_key(&7));
        // a duplicate id overwrites the entry with a different token…
        let t2 = CancelToken::new();
        let r2 = CancelRegistration::register(&map, 7, t2.clone());
        drop(r1); // …so the first guard must not remove the second's entry
        assert!(lock_cancels(&map).get(&7).is_some_and(|t| t.same(&t2)));
        drop(r2);
        assert!(!lock_cancels(&map).contains_key(&7));
    }
}
