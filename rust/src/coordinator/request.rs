//! Request/response types and the caching-policy vocabulary.

use std::time::Instant;

use crate::util::error::Result;

use crate::model::Cond;
use crate::pipeline::GenStats;
use crate::solvers::SolverKind;
use crate::tensor::Tensor;

/// Caching policy a request selects (resolved to a concrete
/// [`crate::cache::Schedule`] by the executor; SmoothCache policies
/// trigger a one-time calibration per (family, solver, steps)).
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// every branch computes at every step (the paper's baseline rows).
    NoCache,
    /// FORA-style uniform caching: compute every n-th step.
    Fora(usize),
    /// L2C-proxy: cache every other step.
    Alternate,
    /// the paper's method, α threshold (grouped decisions).
    Smooth(f64),
    /// grouping ablation: per-site decisions at α.
    SmoothPerSite(f64),
    /// δ-DiT-style depth-aware baseline (refresh interval n).
    DeltaDit(usize),
}

impl Policy {
    /// Parse the wire format: `no-cache`, `fora:2`, `alternate`,
    /// `smooth:0.18`, `smooth-persite:0.18`.
    pub fn parse(s: &str) -> Result<Policy> {
        if s == "no-cache" {
            return Ok(Policy::NoCache);
        }
        if s == "alternate" {
            return Ok(Policy::Alternate);
        }
        if let Some(n) = s.strip_prefix("fora:") {
            return Ok(Policy::Fora(n.parse().map_err(|_| crate::err!("bad fora n: {n}"))?));
        }
        if let Some(a) = s.strip_prefix("smooth-persite:") {
            return Ok(Policy::SmoothPerSite(
                a.parse().map_err(|_| crate::err!("bad alpha: {a}"))?,
            ));
        }
        if let Some(a) = s.strip_prefix("smooth:") {
            return Ok(Policy::Smooth(a.parse().map_err(|_| crate::err!("bad alpha: {a}"))?));
        }
        if let Some(n) = s.strip_prefix("delta-dit:") {
            return Ok(Policy::DeltaDit(n.parse().map_err(|_| crate::err!("bad delta-dit n: {n}"))?));
        }
        Err(crate::err!("unknown policy {s:?}"))
    }

    /// Render the wire format [`Policy::parse`] accepts.
    pub fn wire(&self) -> String {
        match self {
            Policy::NoCache => "no-cache".into(),
            Policy::Fora(n) => format!("fora:{n}"),
            Policy::Alternate => "alternate".into(),
            Policy::Smooth(a) => format!("smooth:{a}"),
            Policy::SmoothPerSite(a) => format!("smooth-persite:{a}"),
            Policy::DeltaDit(n) => format!("delta-dit:{n}"),
        }
    }
}

/// One generation request (single sample; the batcher groups them).
#[derive(Clone, Debug)]
pub struct Request {
    /// Unique id; 0 lets the coordinator assign one at submit time.
    pub id: u64,
    /// Model family (`image`, `audio`, `video`).
    pub family: String,
    /// Conditioning input (class label or prompt token ids).
    pub cond: Cond,
    /// Diffusion solver to run.
    pub solver: SolverKind,
    /// Sampling steps.
    pub steps: usize,
    /// Classifier-free-guidance scale; 1.0 disables CFG.
    pub cfg_scale: f32,
    /// Seed for the initial latent and stochastic solvers.
    pub seed: u64,
    /// Caching policy to resolve and execute.
    pub policy: Policy,
}

impl Request {
    /// Compatibility key: requests sharing a key can run in one batch.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            family: self.family.clone(),
            solver: self.solver,
            steps: self.steps,
            cfg_milli: (self.cfg_scale * 1000.0).round() as u32,
            policy: self.policy.wire(),
        }
    }
}

/// The batching compatibility key (see [`Request::batch_key`]).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// Model family.
    pub family: String,
    /// Diffusion solver.
    pub solver: SolverKind,
    /// Sampling steps.
    pub steps: usize,
    /// CFG scale in milli-units (so the key stays `Eq + Hash`).
    pub cfg_milli: u32,
    /// Caching policy in wire form.
    pub policy: String,
}

/// Completed generation for one request.
#[derive(Debug)]
pub struct Response {
    /// The request id this response answers.
    pub id: u64,
    /// `[1, …latent]`
    pub latent: Tensor,
    /// Executed batch size after dynamic batching + padding.
    pub batch_size: usize,
    /// Submit → batch-execution-start delay for this request.
    pub queue_seconds: f64,
    /// Model execution time of the batch that served this request.
    pub exec_seconds: f64,
    /// End-to-end submit → response time.
    pub total_seconds: f64,
    /// Branch compute/reuse counters from the generation.
    pub gen_stats: GenStats,
}

/// A request travelling through the coordinator with its reply channel.
#[derive(Debug)]
pub struct InFlight {
    /// The request itself.
    pub request: Request,
    /// When the coordinator accepted the request.
    pub submitted: Instant,
    /// Single-use reply channel back to the submitter. Invariant:
    /// exactly one message is ever sent on it — a response, an
    /// execution error, or an `overloaded:` admission rejection.
    pub reply: std::sync::mpsc::Sender<Result<Response>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_wire_roundtrip() {
        for p in [
            Policy::NoCache,
            Policy::Fora(3),
            Policy::Alternate,
            Policy::Smooth(0.18),
            Policy::SmoothPerSite(0.05),
            Policy::DeltaDit(3),
        ] {
            assert_eq!(Policy::parse(&p.wire()).unwrap(), p);
        }
        assert!(Policy::parse("bogus").is_err());
        assert!(Policy::parse("fora:x").is_err());
    }

    #[test]
    fn batch_key_groups_compatible_requests() {
        let mk = |seed: u64, label: i32| Request {
            id: seed,
            family: "image".into(),
            cond: Cond::Label(vec![label]),
            solver: SolverKind::Ddim,
            steps: 50,
            cfg_scale: 1.5,
            seed,
            policy: Policy::Smooth(0.18),
        };
        assert_eq!(mk(1, 3).batch_key(), mk(2, 7).batch_key());
        let mut other = mk(3, 1);
        other.steps = 30;
        assert_ne!(mk(1, 3).batch_key(), other.batch_key());
        let mut pol = mk(4, 1);
        pol.policy = Policy::NoCache;
        assert_ne!(mk(1, 3).batch_key(), pol.batch_key());
    }
}
