//! Request/response types and the caching-policy vocabulary.

use std::sync::Arc;
use std::time::Instant;

use crate::util::error::Result;

use super::cancel::{CancelRegistration, CancelToken, Deadline, DeadlinePolicy, Progress};
use crate::cache::plan::{parse_policy, Planner};
use crate::obs::TraceHandle;
use crate::model::Cond;
use crate::pipeline::GenStats;
use crate::solvers::SolverKind;
use crate::tensor::{ComputeMode, Tensor};

/// Caching policy a request selects: a parsed wire string bound to its
/// [`Planner`] from the policy registry
/// ([`crate::cache::plan::registry`]). The executor resolves it to a
/// concrete [`crate::cache::CachePlan`] (cached per configuration in
/// the pool-shared plan store) or drives its
/// [`crate::cache::StepPlanner`] at runtime; policies whose planner
/// [`Planner::needs_curves`] trigger a one-time calibration per
/// (family, solver, steps).
///
/// Equality, hashing inputs ([`Request::batch_key`]) and `Debug` all go
/// through the canonical wire string, so two spellings of the same
/// policy batch together.
#[derive(Clone)]
pub struct Policy {
    wire: String,
    planner: Arc<dyn Planner>,
}

impl Policy {
    /// Parse the wire format through the policy registry: `no-cache`,
    /// `fora:2`, `alternate`, `smooth:0.18`, `smooth-persite:0.18`,
    /// `delta-dit:2`, `drift:0.3`. Parameters are validated here —
    /// malformed wire input returns an error instead of panicking an
    /// executor later.
    pub fn parse(s: &str) -> Result<Policy> {
        let planner = parse_policy(s)?;
        Ok(Policy { wire: planner.wire(), planner })
    }

    /// The canonical wire form ([`Policy::parse`] round-trips it).
    pub fn wire(&self) -> &str {
        &self.wire
    }

    /// The planner behind this policy.
    pub fn planner(&self) -> &dyn Planner {
        self.planner.as_ref()
    }

    /// Whether resolving needs calibrated error curves (lane hint: such
    /// policies may pay a cold calibration on first use).
    pub fn needs_curves(&self) -> bool {
        self.planner.needs_curves()
    }

    /// `no-cache` (every branch computes at every step).
    pub fn no_cache() -> Policy {
        Policy::parse("no-cache").expect("registry")
    }

    /// `fora:N`. Panics if `n == 0` (use [`Policy::parse`] for wire input).
    pub fn fora(n: usize) -> Policy {
        Policy::parse(&format!("fora:{n}")).expect("fora interval must be >= 1")
    }

    /// `alternate` (cache every other step).
    pub fn alternate() -> Policy {
        Policy::parse("alternate").expect("registry")
    }

    /// `smooth:ALPHA`. Panics on non-finite or negative alphas (use
    /// [`Policy::parse`] for wire input).
    pub fn smooth(alpha: f64) -> Policy {
        Policy::parse(&format!("smooth:{alpha}")).expect("alpha must be finite and >= 0")
    }

    /// `smooth-persite:ALPHA`. Panics on non-finite or negative alphas.
    pub fn smooth_per_site(alpha: f64) -> Policy {
        Policy::parse(&format!("smooth-persite:{alpha}"))
            .expect("alpha must be finite and >= 0")
    }

    /// `delta-dit:N`. Panics if `n == 0`.
    pub fn delta_dit(n: usize) -> Policy {
        Policy::parse(&format!("delta-dit:{n}")).expect("delta-dit interval must be >= 1")
    }

    /// `drift:BOUND` (runtime-adaptive error feedback, default gap cap).
    /// Panics on non-finite or non-positive bounds.
    pub fn drift(bound: f64) -> Policy {
        Policy::parse(&format!("drift:{bound}")).expect("drift bound must be finite and > 0")
    }
}

impl PartialEq for Policy {
    fn eq(&self, other: &Policy) -> bool {
        self.wire == other.wire
    }
}

impl std::fmt::Debug for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Policy({})", self.wire)
    }
}

/// Scheduling priority class a request runs under (the preemptive
/// scheduler's tenant axis; docs/adr/007).
///
/// `Interactive` (the default) is served first and can *preempt*
/// running `Batch` work at a solver-step boundary: the executor parks
/// the in-flight [`crate::pipeline::GenSession`] back into the work
/// queue and runs the interactive batch immediately. `Batch` is for
/// throughput jobs whose latency does not matter — they fill idle
/// capacity and resume after being preempted with results bitwise
/// identical to an uninterrupted run (pinned by
/// `tests/coordinator_props.rs`). Part of the [`BatchKey`], so the two
/// classes never share a batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum PriorityClass {
    /// Latency-sensitive traffic: served first, never preempted.
    #[default]
    Interactive,
    /// Throughput traffic: preemptible at solver-step boundaries,
    /// protected from starvation by the queue's aging rule.
    Batch,
}

impl PriorityClass {
    /// Parse the wire spelling (`interactive` | `batch`).
    pub fn parse(s: &str) -> Option<PriorityClass> {
        match s {
            "interactive" => Some(PriorityClass::Interactive),
            "batch" => Some(PriorityClass::Batch),
            _ => None,
        }
    }

    /// Canonical wire spelling ([`PriorityClass::parse`] round-trips it).
    pub fn name(&self) -> &'static str {
        match self {
            PriorityClass::Interactive => "interactive",
            PriorityClass::Batch => "batch",
        }
    }
}

/// One generation request (single sample; the batcher groups them).
#[derive(Clone, Debug)]
pub struct Request {
    /// Unique id; 0 lets the coordinator assign one at submit time.
    pub id: u64,
    /// Model family (`image`, `audio`, `video`).
    pub family: String,
    /// Conditioning input (class label or prompt token ids).
    pub cond: Cond,
    /// Diffusion solver to run.
    pub solver: SolverKind,
    /// Sampling steps.
    pub steps: usize,
    /// Classifier-free-guidance scale; 1.0 disables CFG.
    pub cfg_scale: f32,
    /// Seed for the initial latent and stochastic solvers.
    pub seed: u64,
    /// Caching policy to resolve and execute.
    pub policy: Policy,
    /// Weight-matmul precision for the whole trajectory (`f32` default;
    /// reduced modes are opt-in — see docs/adr/006). Part of the batch
    /// key: requests at different precisions never share a batch.
    pub compute: ComputeMode,
    /// Scheduling priority class (`interactive` default). Part of the
    /// batch key: the preemptive scheduler never mixes classes in one
    /// batch, so preempting a batch-class group can never stall an
    /// interactive rider.
    pub priority: PriorityClass,
}

impl Request {
    /// Compatibility key: requests sharing a key can run in one batch.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            family: self.family.clone(),
            solver: self.solver,
            steps: self.steps,
            cfg_milli: (self.cfg_scale * 1000.0).round() as u32,
            policy: self.policy.wire().to_string(),
            compute: self.compute,
            priority: self.priority,
        }
    }
}

/// The batching compatibility key (see [`Request::batch_key`]).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// Model family.
    pub family: String,
    /// Diffusion solver.
    pub solver: SolverKind,
    /// Sampling steps.
    pub steps: usize,
    /// CFG scale in milli-units (so the key stays `Eq + Hash`).
    pub cfg_milli: u32,
    /// Caching policy in canonical wire form.
    pub policy: String,
    /// Weight-matmul precision; mixed-precision batches are never formed.
    pub compute: ComputeMode,
    /// Scheduling priority class; mixed-class batches are never formed.
    pub priority: PriorityClass,
}

/// Completed generation for one request.
#[derive(Debug)]
pub struct Response {
    /// The request id this response answers.
    pub id: u64,
    /// `[1, …latent]`
    pub latent: Tensor,
    /// Executed batch size after dynamic batching + padding.
    pub batch_size: usize,
    /// Solver steps the generation executed (the full trajectory; an
    /// aborted generation never produces a `Response`).
    pub steps_completed: usize,
    /// True when the request carried a best-effort deadline and the
    /// response is late (reject-late deadlines answer a `deadline:`
    /// error instead).
    pub deadline_missed: bool,
    /// Submit → batch-execution-start delay for this request.
    pub queue_seconds: f64,
    /// Model execution time of the batch that served this request.
    pub exec_seconds: f64,
    /// End-to-end submit → response time.
    pub total_seconds: f64,
    /// Branch compute/reuse counters from the generation.
    pub gen_stats: GenStats,
}

/// A request travelling through the coordinator with its reply channel
/// and transport state (cancellation token, optional deadline and
/// progress stream). Build one with [`InFlight::new`]; the coordinator
/// attaches deadline/progress/registry state at submit.
#[derive(Debug)]
pub struct InFlight {
    /// The request itself.
    pub request: Request,
    /// When the coordinator accepted the request.
    pub submitted: Instant,
    /// Single-use reply channel back to the submitter. Invariant:
    /// exactly one message is ever sent on it — a response, an
    /// execution error, an `overloaded:` admission rejection, a
    /// `cancelled:` abort or a `deadline:` rejection.
    pub reply: std::sync::mpsc::Sender<Result<Response>>,
    /// Cooperative cancellation flag, checked by the batcher at flush,
    /// by queue purges, and by executors between solver steps.
    pub cancel: CancelToken,
    /// Optional latency budget (see [`super::cancel::Deadline`]).
    pub deadline: Option<Deadline>,
    /// Optional per-step progress stream (streaming clients).
    pub progress: Option<std::sync::mpsc::Sender<Progress>>,
    /// Per-request trace context (docs/adr/009). Instrumentation at
    /// every pipeline seam records into it; a disabled handle (tracing
    /// `off`) costs one branch per site. The terminal path that answers
    /// the request also finishes the trace into the flight recorder.
    pub trace: TraceHandle,
    /// Registry drop guard: removes the cancel token from the
    /// coordinator's id map when this request is answered on any path.
    pub(super) registration: Option<CancelRegistration>,
}

impl InFlight {
    /// Wrap a request and its reply channel with default transport
    /// state: a fresh cancel token, no deadline, no progress stream.
    pub fn new(request: Request, reply: std::sync::mpsc::Sender<Result<Response>>) -> InFlight {
        InFlight {
            request,
            submitted: Instant::now(),
            reply,
            cancel: CancelToken::new(),
            deadline: None,
            progress: None,
            trace: TraceHandle::off(),
            registration: None,
        }
    }

    /// True when this request must not start executing: it was
    /// cancelled, or its reject-late deadline has already expired
    /// (best-effort deadlines still run). The batcher, queue purge and
    /// executor pre-filter all shed on this predicate.
    pub fn dead_on_arrival(&self) -> bool {
        self.cancel.is_cancelled()
            || self
                .deadline
                .is_some_and(|d| d.policy == DeadlinePolicy::RejectLate && d.expired())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_wire_roundtrip() {
        for p in [
            Policy::no_cache(),
            Policy::fora(3),
            Policy::alternate(),
            Policy::smooth(0.18),
            Policy::smooth_per_site(0.05),
            Policy::delta_dit(3),
            Policy::drift(0.3),
        ] {
            assert_eq!(Policy::parse(p.wire()).unwrap(), p);
        }
        assert!(Policy::parse("bogus").is_err());
        assert!(Policy::parse("fora:x").is_err());
    }

    #[test]
    fn policy_parse_validates_parameters_from_wire() {
        // these used to parse fine and panic (or misbehave) deep inside
        // an executor replica; now they fail at the wire boundary
        assert!(Policy::parse("fora:0").is_err());
        assert!(Policy::parse("delta-dit:0").is_err());
        assert!(Policy::parse("smooth:NaN").is_err());
        assert!(Policy::parse("smooth:inf").is_err());
        assert!(Policy::parse("drift:0").is_err());
    }

    #[test]
    fn policy_lane_hints_come_from_the_registry() {
        assert!(!Policy::no_cache().needs_curves());
        assert!(!Policy::fora(2).needs_curves());
        assert!(!Policy::delta_dit(2).needs_curves());
        assert!(!Policy::drift(0.3).needs_curves());
        assert!(Policy::smooth(0.2).needs_curves());
        assert!(Policy::smooth_per_site(0.2).needs_curves());
        // exactly the dynamic policies expose a StepPlanner
        assert!(Policy::drift(0.3).planner().dynamic().is_some());
        assert!(Policy::smooth(0.2).planner().dynamic().is_none());
    }

    #[test]
    fn batch_key_groups_compatible_requests() {
        let mk = |seed: u64, label: i32| Request {
            id: seed,
            family: "image".into(),
            cond: Cond::Label(vec![label]),
            solver: SolverKind::Ddim,
            steps: 50,
            cfg_scale: 1.5,
            seed,
            policy: Policy::smooth(0.18),
            compute: ComputeMode::F32,
            priority: PriorityClass::default(),
        };
        assert_eq!(mk(1, 3).batch_key(), mk(2, 7).batch_key());
        let mut other = mk(3, 1);
        other.steps = 30;
        assert_ne!(mk(1, 3).batch_key(), other.batch_key());
        let mut pol = mk(4, 1);
        pol.policy = Policy::no_cache();
        assert_ne!(mk(1, 3).batch_key(), pol.batch_key());
        // precision is part of the key: an int8 request must not share a
        // batch with an f32 one
        let mut quant = mk(5, 1);
        quant.compute = ComputeMode::Int8;
        assert_ne!(mk(1, 3).batch_key(), quant.batch_key());
        // priority class is part of the key: a batch-class request must
        // not share a batch with an interactive one (preempting the
        // group would stall its interactive riders)
        let mut low = mk(6, 1);
        low.priority = PriorityClass::Batch;
        assert_ne!(mk(1, 3).batch_key(), low.batch_key());
    }

    #[test]
    fn priority_class_wire_roundtrip_and_default() {
        assert_eq!(PriorityClass::default(), PriorityClass::Interactive);
        for p in [PriorityClass::Interactive, PriorityClass::Batch] {
            assert_eq!(PriorityClass::parse(p.name()), Some(p));
        }
        assert_eq!(PriorityClass::parse("urgent"), None);
        assert_eq!(PriorityClass::parse(""), None);
    }
}
