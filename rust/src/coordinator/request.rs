//! Request/response types and the caching-policy vocabulary.

use std::time::Instant;

use crate::util::error::Result;

use crate::model::Cond;
use crate::pipeline::GenStats;
use crate::solvers::SolverKind;
use crate::tensor::Tensor;

/// Caching policy a request selects (resolved to a concrete
/// [`crate::cache::Schedule`] by the executor; SmoothCache policies
/// trigger a one-time calibration per (family, solver, steps)).
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    NoCache,
    Fora(usize),
    Alternate,
    /// the paper's method, α threshold (grouped decisions).
    Smooth(f64),
    /// grouping ablation: per-site decisions at α.
    SmoothPerSite(f64),
    /// δ-DiT-style depth-aware baseline (refresh interval n).
    DeltaDit(usize),
}

impl Policy {
    /// Parse the wire format: `no-cache`, `fora:2`, `alternate`,
    /// `smooth:0.18`, `smooth-persite:0.18`.
    pub fn parse(s: &str) -> Result<Policy> {
        if s == "no-cache" {
            return Ok(Policy::NoCache);
        }
        if s == "alternate" {
            return Ok(Policy::Alternate);
        }
        if let Some(n) = s.strip_prefix("fora:") {
            return Ok(Policy::Fora(n.parse().map_err(|_| crate::err!("bad fora n: {n}"))?));
        }
        if let Some(a) = s.strip_prefix("smooth-persite:") {
            return Ok(Policy::SmoothPerSite(
                a.parse().map_err(|_| crate::err!("bad alpha: {a}"))?,
            ));
        }
        if let Some(a) = s.strip_prefix("smooth:") {
            return Ok(Policy::Smooth(a.parse().map_err(|_| crate::err!("bad alpha: {a}"))?));
        }
        if let Some(n) = s.strip_prefix("delta-dit:") {
            return Ok(Policy::DeltaDit(n.parse().map_err(|_| crate::err!("bad delta-dit n: {n}"))?));
        }
        Err(crate::err!("unknown policy {s:?}"))
    }

    pub fn wire(&self) -> String {
        match self {
            Policy::NoCache => "no-cache".into(),
            Policy::Fora(n) => format!("fora:{n}"),
            Policy::Alternate => "alternate".into(),
            Policy::Smooth(a) => format!("smooth:{a}"),
            Policy::SmoothPerSite(a) => format!("smooth-persite:{a}"),
            Policy::DeltaDit(n) => format!("delta-dit:{n}"),
        }
    }
}

/// One generation request (single sample; the batcher groups them).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub family: String,
    pub cond: Cond,
    pub solver: SolverKind,
    pub steps: usize,
    pub cfg_scale: f32,
    pub seed: u64,
    pub policy: Policy,
}

impl Request {
    /// Compatibility key: requests sharing a key can run in one batch.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            family: self.family.clone(),
            solver: self.solver,
            steps: self.steps,
            cfg_milli: (self.cfg_scale * 1000.0).round() as u32,
            policy: self.policy.wire(),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub family: String,
    pub solver: SolverKind,
    pub steps: usize,
    pub cfg_milli: u32,
    pub policy: String,
}

/// Completed generation for one request.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// `[1, …latent]`
    pub latent: Tensor,
    pub batch_size: usize,
    pub queue_seconds: f64,
    pub exec_seconds: f64,
    pub total_seconds: f64,
    pub gen_stats: GenStats,
}

/// A request travelling through the coordinator with its reply channel.
pub struct InFlight {
    pub request: Request,
    pub submitted: Instant,
    pub reply: std::sync::mpsc::Sender<Result<Response>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_wire_roundtrip() {
        for p in [
            Policy::NoCache,
            Policy::Fora(3),
            Policy::Alternate,
            Policy::Smooth(0.18),
            Policy::SmoothPerSite(0.05),
            Policy::DeltaDit(3),
        ] {
            assert_eq!(Policy::parse(&p.wire()).unwrap(), p);
        }
        assert!(Policy::parse("bogus").is_err());
        assert!(Policy::parse("fora:x").is_err());
    }

    #[test]
    fn batch_key_groups_compatible_requests() {
        let mk = |seed: u64, label: i32| Request {
            id: seed,
            family: "image".into(),
            cond: Cond::Label(vec![label]),
            solver: SolverKind::Ddim,
            steps: 50,
            cfg_scale: 1.5,
            seed,
            policy: Policy::Smooth(0.18),
        };
        assert_eq!(mk(1, 3).batch_key(), mk(2, 7).batch_key());
        let mut other = mk(3, 1);
        other.steps = 30;
        assert_ne!(mk(1, 3).batch_key(), other.batch_key());
        let mut pol = mk(4, 1);
        pol.policy = Policy::NoCache;
        assert_ne!(mk(1, 3).batch_key(), pol.batch_key());
    }
}
