//! The shared pull-model work queue between the batcher and the
//! executor replica pool (ADR-002), extended into the preemptive
//! scheduler's run queue (docs/adr/007).
//!
//! One bounded MPMC queue replaces the per-replica channels the
//! round-robin `Router` used to feed: the batcher pushes every flushed
//! batch here, and each executor pulls its next work item the moment it
//! goes idle. A replica stuck in a long calibration simply stops
//! pulling — it can no longer head-of-line-block batches a sibling
//! could serve, which was the failure mode recorded in ROADMAP.md after
//! the PR 2 review.
//!
//! The queue holds two kinds of [`WorkItem`]:
//!
//! * **Fresh batches** ([`QueuedBatch`]), organized by the request's
//!   [`PriorityClass`] (interactive | batch) and, within a class, by
//!   calibration [`Lane`] (priority = resolves without a cold
//!   calibration, normal = will pay one). Within a (class, lane) pair,
//!   order is FIFO.
//! * **Parked sessions** ([`ParkedSession`]): in-flight generations an
//!   executor preempted at a solver-step boundary to let interactive
//!   work through. They carry the full [`SessionState`] snapshot plus
//!   the original requests, and resume on *any* replica
//!   bitwise-identically (pinned by `tests/coordinator_props.rs`).
//!
//! Pick order in [`WorkQueue::pop`]:
//!
//! 1. **Aging override** — if [`WorkQueue::aging_limit`] consecutive
//!    interactive items were served while lower-class work waited, the
//!    oldest lower-class item (parked first) is served next. This is
//!    the anti-starvation rule: under a *sustained* interactive flood
//!    every parked session still gets one resume slot per
//!    `aging_limit + 1` pops, and since a resumed session always makes
//!    ≥ 1 step of progress before it can be preempted again, every
//!    parked session finishes in at most `steps × (aging_limit + 1)`
//!    pops. Deterministic (count-based, not wall-clock), so it is
//!    propcheck-testable without sleeps.
//! 2. Interactive fresh batches (priority lane, then normal).
//! 3. Parked sessions, FIFO — resuming partial work bounds park depth
//!    and memory before new batch-class work is admitted to a replica.
//! 4. Batch-class fresh batches (priority lane, then normal).
//!
//! Three properties carried over from ADR-002 and sharpened:
//!
//! * **Bounded depth / admission control** — at most `depth` *fresh*
//!   requests (summed over queued batches, both classes) wait at any
//!   time; a push that would exceed the bound is rejected and the whole
//!   batch handed back so the caller can answer each request with an
//!   `overloaded:` error. An empty queue always admits one batch. Parked
//!   sessions do **not** consume admission slots (they were admitted
//!   once already; holding their slot while parked would let a preempted
//!   long job block new traffic — the accounting the ISSUE calls out)
//!   and [`WorkQueue::push_parked`] never fails: a preempted session
//!   must always be able to re-enter, or its work would be lost.
//! * **Preemption signal** — [`WorkQueue::should_preempt`] tells an
//!   executor mid-generation whether fresh work of a strictly higher
//!   class is waiting; it never blocks.
//! * **Graceful drain** — [`WorkQueue::close`] stops fresh admissions
//!   while letting executors drain everything already queued, parked
//!   sessions included; [`WorkQueue::pop`] returns `None` only once the
//!   queue is closed **and** fully drained.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use super::request::{InFlight, PriorityClass};
use crate::pipeline::SessionState;

/// Which calibration lane a batch enters its class on. See the module
/// docs for the overtaking semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Served first within the class: the batch's policy resolves
    /// without a cold calibration, so an idle replica can run it
    /// immediately.
    Priority,
    /// Served when the class's priority lane is empty: the batch will
    /// trigger (or wait on) an expensive calibration.
    Normal,
}

/// A fresh batch travelling through the queue, stamped at admission so
/// the executor that pops it can account queue wait separately from
/// execution time ([`super::Metrics::queue_wait`]).
pub struct QueuedBatch {
    /// The flushed batch (homogeneous in [`super::BatchKey`] by
    /// construction — the batcher never mixes keys, so the whole batch
    /// shares one [`PriorityClass`]).
    pub batch: Vec<InFlight>,
    /// When [`WorkQueue::push`] admitted the batch.
    pub enqueued: Instant,
    /// The calibration lane the batch was admitted on.
    pub lane: Lane,
}

impl QueuedBatch {
    /// The batch's priority class (from its first member; homogeneous
    /// by construction).
    pub fn class(&self) -> PriorityClass {
        self.batch
            .first()
            .map(|it| it.request.priority)
            .unwrap_or_default()
    }
}

/// An in-flight generation an executor preempted at a solver-step
/// boundary: the full [`SessionState`] snapshot plus the requests it
/// serves, their latent rows, and the timing state needed to account
/// the eventual response correctly. Holds **no** admission slot while
/// parked.
pub struct ParkedSession {
    /// The surviving batch members as `(latent row, request)` — the row
    /// indexes the session's padded latent, so cancelling one member
    /// never shifts its siblings' samples.
    pub members: Vec<(usize, InFlight)>,
    /// The step-boundary snapshot to resume from.
    pub state: SessionState,
    /// The padded batch size the session executes at (the `batch_size`
    /// reported on each member's [`super::Response`]).
    pub target: usize,
    /// Priority class of the parked work (its members' class).
    pub class: PriorityClass,
    /// Model execution seconds accumulated over earlier segments.
    pub exec_seconds: f64,
    /// When the batch *first* started executing (per-member
    /// `queue_seconds` keeps meaning submit → first execution start).
    pub first_exec: Instant,
    /// When the session was parked ([`super::Metrics::resume_latency`]
    /// measures park → next pop).
    pub parked_at: Instant,
}

/// One unit of executor work: a fresh batch to start, or a parked
/// session to resume.
pub enum WorkItem {
    /// A fresh batch from the batcher.
    Fresh(QueuedBatch),
    /// A preempted session to resume.
    Parked(ParkedSession),
}

#[derive(Default)]
struct ClassLanes {
    prio: VecDeque<QueuedBatch>,
    normal: VecDeque<QueuedBatch>,
}

impl ClassLanes {
    fn pop(&mut self) -> Option<QueuedBatch> {
        self.prio.pop_front().or_else(|| self.normal.pop_front())
    }

    fn is_empty(&self) -> bool {
        self.prio.is_empty() && self.normal.is_empty()
    }
}

struct State {
    interactive: ClassLanes,
    batch: ClassLanes,
    parked: VecDeque<ParkedSession>,
    /// Invariant: always equals the sum of `batch.len()` over the fresh
    /// lanes of both classes (parked members are never counted).
    queued_requests: usize,
    /// Consecutive interactive serves while lower-class work waited
    /// (the aging rule's counter; reset whenever lower-class work is
    /// served or none is waiting).
    high_serves: usize,
    open: bool,
}

/// Bounded class-aware MPMC work queue (`Mutex` + `Condvar`; no
/// external crates offline). Producers ([`WorkQueue::push`],
/// [`WorkQueue::push_parked`]) never block — fresh admission either
/// succeeds or fails immediately, parked re-entry always succeeds.
/// Consumers ([`WorkQueue::pop`]) block until work is available or the
/// queue is closed and drained.
pub struct WorkQueue {
    depth: usize,
    aging_limit: usize,
    state: Mutex<State>,
    cv: Condvar,
}

/// Poison recovery: the queue's internal lock is only ever held for a
/// few pointer moves (no user code runs under it), so its state is
/// always consistent even if a holder thread panicked.
fn lock(m: &Mutex<State>) -> std::sync::MutexGuard<'_, State> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl WorkQueue {
    /// Create a queue admitting at most `depth` queued fresh requests
    /// (`depth` is clamped to ≥ 1) with the default aging limit of 4.
    pub fn new(depth: usize) -> WorkQueue {
        WorkQueue::with_aging(depth, 4)
    }

    /// Like [`WorkQueue::new`] with an explicit aging limit: the number
    /// of consecutive interactive serves (while lower-class work waits)
    /// after which the oldest lower-class item is served next. Clamped
    /// to ≥ 1.
    pub fn with_aging(depth: usize, aging_limit: usize) -> WorkQueue {
        WorkQueue {
            depth: depth.max(1),
            aging_limit: aging_limit.max(1),
            state: Mutex::new(State {
                interactive: ClassLanes::default(),
                batch: ClassLanes::default(),
                parked: VecDeque::new(),
                queued_requests: 0,
                high_serves: 0,
                open: true,
            }),
            cv: Condvar::new(),
        }
    }

    /// The configured admission bound, in fresh requests.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The configured anti-starvation aging limit.
    pub fn aging_limit(&self) -> usize {
        self.aging_limit
    }

    /// Fresh requests currently waiting (summed over queued batches of
    /// both classes; excludes parked sessions and batches already
    /// popped by an executor).
    pub fn len(&self) -> usize {
        lock(&self.state).queued_requests
    }

    /// `true` when no fresh batch is waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Parked sessions currently waiting to resume.
    pub fn parked_len(&self) -> usize {
        lock(&self.state).parked.len()
    }

    /// Whether an executor running work of `class` should preempt it at
    /// the next solver-step boundary: `true` iff fresh work of a
    /// strictly higher class is waiting. Never blocks; interactive work
    /// is never preempted.
    pub fn should_preempt(&self, class: PriorityClass) -> bool {
        match class {
            PriorityClass::Interactive => false,
            PriorityClass::Batch => !lock(&self.state).interactive.is_empty(),
        }
    }

    /// Admit a fresh batch on `lane` (its class comes from the
    /// requests), or hand it back when the queue is full (or closed) so
    /// the caller can reject each request with an error. Never blocks.
    pub fn push(&self, batch: Vec<InFlight>, lane: Lane) -> Result<(), Vec<InFlight>> {
        let mut st = lock(&self.state);
        if !st.open {
            return Err(batch);
        }
        let n = batch.len();
        // admit-if-empty: a single batch larger than `depth` must still
        // be servable, otherwise it could never run at any queue state
        if st.queued_requests > 0 && st.queued_requests + n > self.depth {
            return Err(batch);
        }
        st.queued_requests += n;
        let q = QueuedBatch { batch, enqueued: Instant::now(), lane };
        let lanes = match q.class() {
            PriorityClass::Interactive => &mut st.interactive,
            PriorityClass::Batch => &mut st.batch,
        };
        match lane {
            Lane::Priority => lanes.prio.push_back(q),
            Lane::Normal => lanes.normal.push_back(q),
        }
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Re-enter a preempted session. Always succeeds — even after
    /// [`WorkQueue::close`], since a parked session that cannot re-enter
    /// would lose already-admitted, partially-executed work — and never
    /// consumes an admission slot.
    pub fn push_parked(&self, session: ParkedSession) {
        let mut st = lock(&self.state);
        st.parked.push_back(session);
        drop(st);
        self.cv.notify_one();
    }

    /// Pull the next work item per the pick order in the module docs.
    /// Blocks while the queue is open and empty; returns `None` once
    /// the queue is closed **and** fully drained — fresh lanes and
    /// parked sessions both — which is the executor's signal to exit.
    pub fn pop(&self) -> Option<WorkItem> {
        let mut st = lock(&self.state);
        loop {
            let low_waiting = !st.batch.is_empty() || !st.parked.is_empty();
            // 1. aging override: lower-class work has waited through
            // `aging_limit` consecutive interactive serves
            if low_waiting && st.high_serves >= self.aging_limit {
                st.high_serves = 0;
                if let Some(ps) = st.parked.pop_front() {
                    return Some(WorkItem::Parked(ps));
                }
                if let Some(q) = st.batch.pop() {
                    st.queued_requests -= q.batch.len();
                    return Some(WorkItem::Fresh(q));
                }
            }
            // 2. interactive fresh work
            if let Some(q) = st.interactive.pop() {
                st.high_serves = if low_waiting { st.high_serves + 1 } else { 0 };
                st.queued_requests -= q.batch.len();
                return Some(WorkItem::Fresh(q));
            }
            // 3. parked resumes before new batch-class admissions
            if let Some(ps) = st.parked.pop_front() {
                st.high_serves = 0;
                return Some(WorkItem::Parked(ps));
            }
            // 4. batch-class fresh work
            if let Some(q) = st.batch.pop() {
                st.high_serves = 0;
                st.queued_requests -= q.batch.len();
                return Some(WorkItem::Fresh(q));
            }
            if !st.open {
                return None;
            }
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }

    /// Stop fresh admissions and wake every blocked consumer. Work
    /// already queued — fresh batches and parked sessions — remains
    /// poppable (graceful drain); once it is gone, [`WorkQueue::pop`]
    /// returns `None`. Idempotent.
    pub fn close(&self) {
        lock(&self.state).open = false;
        self.cv.notify_all();
    }

    /// Pull every queued request matching `pred` out of the queue —
    /// fresh batches *and* parked sessions — returning them so the
    /// caller can answer each one (cancellation purge,
    /// [`super::Coordinator::cancel`]). Fresh admission slots free
    /// immediately; a parked session whose members all match is dropped
    /// entirely and **never resumes** (its partial work is discarded).
    /// Batches / sessions left empty are dropped; FIFO order of the
    /// rest is untouched.
    pub fn remove_where(&self, pred: impl Fn(&InFlight) -> bool) -> Vec<InFlight> {
        fn take_lane(
            lane: &mut VecDeque<QueuedBatch>,
            pred: &impl Fn(&InFlight) -> bool,
            removed: &mut Vec<InFlight>,
        ) {
            for qb in lane.iter_mut() {
                let mut i = 0;
                while i < qb.batch.len() {
                    if pred(&qb.batch[i]) {
                        removed.push(qb.batch.remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
            lane.retain(|qb| !qb.batch.is_empty());
        }
        let mut removed = Vec::new();
        let mut st = lock(&self.state);
        take_lane(&mut st.interactive.prio, &pred, &mut removed);
        take_lane(&mut st.interactive.normal, &pred, &mut removed);
        take_lane(&mut st.batch.prio, &pred, &mut removed);
        take_lane(&mut st.batch.normal, &pred, &mut removed);
        st.queued_requests -= removed.len();
        // parked members hold no admission slot, so the counter is not
        // touched; an emptied session is dropped and never resumes
        for ps in st.parked.iter_mut() {
            let mut i = 0;
            while i < ps.members.len() {
                if pred(&ps.members[i].1) {
                    removed.push(ps.members.remove(i).1);
                } else {
                    i += 1;
                }
            }
        }
        st.parked.retain(|ps| !ps.members.is_empty());
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::plan::PlanRef;
    use crate::coordinator::request::{Policy, Request};
    use crate::model::{Cond, Engine};
    use crate::pipeline::{GenConfig, GenSession};
    use crate::solvers::SolverKind;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn mk_item(id: u64, class: PriorityClass) -> InFlight {
        let (tx, rx) = channel();
        std::mem::forget(rx); // keep the reply channel alive
        InFlight::new(
            Request {
                id,
                family: "image".into(),
                cond: Cond::Label(vec![1]),
                solver: SolverKind::Ddim,
                steps: 4,
                cfg_scale: 1.0,
                seed: id,
                policy: Policy::no_cache(),
                compute: Default::default(),
                priority: class,
            },
            tx,
        )
    }

    fn mk_batch(ids: &[u64]) -> Vec<InFlight> {
        ids.iter().map(|&id| mk_item(id, PriorityClass::Interactive)).collect()
    }

    fn mk_low_batch(ids: &[u64]) -> Vec<InFlight> {
        ids.iter().map(|&id| mk_item(id, PriorityClass::Batch)).collect()
    }

    fn mk_parked(ids: &[u64]) -> ParkedSession {
        // a real (tiny) session snapshot so ParkedSession is honest
        let mut engine = Engine::open(crate::artifacts_dir()).expect("engine");
        engine.load_family("image").expect("family");
        let policy = Policy::no_cache();
        let plan = policy
            .planner()
            .plan(&crate::cache::plan::PlanCtx {
                family: engine.family_manifest("image").unwrap(),
                solver: SolverKind::Ddim,
                steps: 2,
                curves: None,
            })
            .unwrap();
        let cfg = GenConfig::new("image", SolverKind::Ddim, 2).with_seed(1);
        let cond = Cond::Label(vec![0; ids.len().max(1)]);
        let mut s = GenSession::new(&engine, &cfg, &cond, PlanRef::Plan(&plan)).unwrap();
        s.step().unwrap();
        let state = s.snapshot();
        ParkedSession {
            members: ids
                .iter()
                .enumerate()
                .map(|(row, &id)| (row, mk_item(id, PriorityClass::Batch)))
                .collect(),
            target: ids.len().max(1),
            class: PriorityClass::Batch,
            state,
            exec_seconds: 0.0,
            first_exec: Instant::now(),
            parked_at: Instant::now(),
        }
    }

    fn ids(q: &QueuedBatch) -> Vec<u64> {
        q.batch.iter().map(|it| it.request.id).collect()
    }

    fn pop_fresh(q: &WorkQueue) -> QueuedBatch {
        match q.pop().expect("work") {
            WorkItem::Fresh(b) => b,
            WorkItem::Parked(_) => panic!("expected a fresh batch"),
        }
    }

    fn pop_parked(q: &WorkQueue) -> ParkedSession {
        match q.pop().expect("work") {
            WorkItem::Parked(p) => p,
            WorkItem::Fresh(b) => panic!("expected a parked session, got fresh {:?}", ids(&b)),
        }
    }

    #[test]
    fn fifo_within_lane_priority_overtakes() {
        let q = WorkQueue::new(64);
        q.push(mk_batch(&[1]), Lane::Normal).unwrap();
        q.push(mk_batch(&[2]), Lane::Normal).unwrap();
        q.push(mk_batch(&[3]), Lane::Priority).unwrap();
        q.push(mk_batch(&[4]), Lane::Priority).unwrap();
        assert_eq!(ids(&pop_fresh(&q)), vec![3]);
        assert_eq!(ids(&pop_fresh(&q)), vec![4]);
        assert_eq!(ids(&pop_fresh(&q)), vec![1]);
        assert_eq!(ids(&pop_fresh(&q)), vec![2]);
        assert!(q.is_empty());
    }

    #[test]
    fn interactive_class_overtakes_batch_class_across_lanes() {
        let q = WorkQueue::new(64);
        // batch-class work first, even on its priority lane…
        q.push(mk_low_batch(&[1]), Lane::Priority).unwrap();
        q.push(mk_low_batch(&[2]), Lane::Normal).unwrap();
        // …is overtaken by interactive work, even on its normal lane
        q.push(mk_batch(&[3]), Lane::Normal).unwrap();
        assert_eq!(ids(&pop_fresh(&q)), vec![3]);
        assert_eq!(ids(&pop_fresh(&q)), vec![1]);
        assert_eq!(ids(&pop_fresh(&q)), vec![2]);
        assert!(q.is_empty());
    }

    #[test]
    fn admission_rejects_when_full_and_hands_batch_back() {
        let q = WorkQueue::new(2);
        q.push(mk_batch(&[1, 2]), Lane::Priority).unwrap();
        assert_eq!(q.len(), 2);
        let rejected = q.push(mk_batch(&[3]), Lane::Priority).unwrap_err();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].request.id, 3); // intact, caller can reply
        assert_eq!(q.len(), 2); // rejection did not corrupt accounting
        // draining frees capacity again
        q.pop().unwrap();
        q.push(mk_batch(&[4]), Lane::Normal).unwrap();
    }

    #[test]
    fn empty_queue_admits_oversized_batch() {
        let q = WorkQueue::new(1);
        q.push(mk_batch(&[1, 2, 3]), Lane::Priority).unwrap();
        // but a second batch is over the bound until the first drains
        assert!(q.push(mk_batch(&[4]), Lane::Priority).is_err());
        assert_eq!(ids(&pop_fresh(&q)), vec![1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn parked_sessions_hold_no_admission_slots() {
        let q = WorkQueue::new(2);
        q.push(mk_batch(&[1, 2]), Lane::Priority).unwrap(); // queue full
        // a parked session re-enters anyway and does not count
        q.push_parked(mk_parked(&[10, 11]));
        assert_eq!(q.len(), 2, "parked members must not consume fresh slots");
        assert_eq!(q.parked_len(), 1);
        // fresh admission is still governed only by fresh requests
        assert!(q.push(mk_batch(&[3]), Lane::Priority).is_err());
        q.pop().unwrap(); // drains the fresh batch
        q.push(mk_batch(&[3]), Lane::Priority).unwrap();
    }

    #[test]
    fn parked_resumes_before_fresh_batch_class_but_after_interactive() {
        let q = WorkQueue::new(64);
        q.push(mk_low_batch(&[1]), Lane::Priority).unwrap();
        q.push_parked(mk_parked(&[10]));
        q.push(mk_batch(&[2]), Lane::Priority).unwrap();
        // interactive first, then the parked resume, then fresh batch-class
        assert_eq!(ids(&pop_fresh(&q)), vec![2]);
        let ps = pop_parked(&q);
        assert_eq!(ps.members[0].1.request.id, 10);
        assert_eq!(ids(&pop_fresh(&q)), vec![1]);
    }

    #[test]
    fn aging_limit_bounds_starvation_under_interactive_flood() {
        let limit = 3;
        let q = WorkQueue::with_aging(64, limit);
        assert_eq!(q.aging_limit(), limit);
        q.push_parked(mk_parked(&[99]));
        // a sustained interactive flood: always more interactive work
        // waiting than pops taken
        for id in 0..10 {
            q.push(mk_batch(&[id]), Lane::Priority).unwrap();
        }
        // exactly `limit` interactive serves, then the parked session
        let mut interactive_serves = 0;
        loop {
            match q.pop().expect("work") {
                WorkItem::Fresh(b) => {
                    assert_eq!(b.class(), PriorityClass::Interactive);
                    interactive_serves += 1;
                    assert!(
                        interactive_serves <= limit,
                        "parked session starved past the aging limit"
                    );
                }
                WorkItem::Parked(ps) => {
                    assert_eq!(ps.members[0].1.request.id, 99);
                    break;
                }
            }
        }
        assert_eq!(interactive_serves, limit);
    }

    #[test]
    fn aging_also_rescues_fresh_batch_class_work() {
        let limit = 2;
        let q = WorkQueue::with_aging(64, limit);
        q.push(mk_low_batch(&[50]), Lane::Priority).unwrap();
        for id in 0..6 {
            q.push(mk_batch(&[id]), Lane::Priority).unwrap();
        }
        let mut order = Vec::new();
        for _ in 0..4 {
            order.push(ids(&pop_fresh(&q))[0]);
        }
        // two interactive serves, then the aged batch-class item
        assert_eq!(order, vec![0, 1, 50, 2]);
    }

    #[test]
    fn should_preempt_only_for_batch_class_with_interactive_waiting() {
        let q = WorkQueue::new(64);
        assert!(!q.should_preempt(PriorityClass::Batch), "empty queue");
        assert!(!q.should_preempt(PriorityClass::Interactive));
        q.push(mk_low_batch(&[1]), Lane::Priority).unwrap();
        assert!(
            !q.should_preempt(PriorityClass::Batch),
            "waiting batch-class work must not preempt batch-class work"
        );
        q.push(mk_batch(&[2]), Lane::Normal).unwrap();
        assert!(q.should_preempt(PriorityClass::Batch));
        assert!(
            !q.should_preempt(PriorityClass::Interactive),
            "interactive work is never preempted"
        );
    }

    #[test]
    fn close_drains_fresh_and_parked_then_signals_exit() {
        let q = WorkQueue::new(8);
        q.push(mk_batch(&[1]), Lane::Normal).unwrap();
        q.push_parked(mk_parked(&[10]));
        q.close();
        // fresh pushes after close are rejected…
        assert!(q.push(mk_batch(&[3]), Lane::Priority).is_err());
        // …but a parked session still re-enters (its work must drain)
        q.push_parked(mk_parked(&[11]));
        assert_eq!(ids(&pop_fresh(&q)), vec![1]);
        assert_eq!(pop_parked(&q).members[0].1.request.id, 10);
        assert_eq!(pop_parked(&q).members[0].1.request.id, 11);
        assert!(q.pop().is_none());
        assert!(q.pop().is_none()); // idempotent
    }

    #[test]
    fn remove_where_frees_slots_and_drops_empty_batches() {
        let q = WorkQueue::new(4);
        q.push(mk_batch(&[1, 2]), Lane::Priority).unwrap();
        q.push(mk_batch(&[3]), Lane::Normal).unwrap();
        assert_eq!(q.len(), 3);
        // queue is full enough that another 2-request batch is rejected
        assert!(q.push(mk_batch(&[4, 5]), Lane::Normal).is_err());

        // purge one request out of the priority batch and the whole
        // normal batch — slots free immediately
        let removed = q.remove_where(|it| it.request.id == 2 || it.request.id == 3);
        assert_eq!(
            removed.iter().map(|it| it.request.id).collect::<Vec<_>>(),
            vec![2, 3]
        );
        assert_eq!(q.len(), 1);
        // freed capacity admits the batch that was rejected above
        q.push(mk_batch(&[4, 5]), Lane::Normal).unwrap();

        // the emptied normal batch is gone; the surviving priority
        // request still pops first, then the new batch
        assert_eq!(ids(&pop_fresh(&q)), vec![1]);
        assert_eq!(ids(&pop_fresh(&q)), vec![4, 5]);
        assert!(q.is_empty());
        assert!(q.remove_where(|_| true).is_empty());
    }

    #[test]
    fn remove_where_purges_parked_members_and_drops_empty_sessions() {
        let q = WorkQueue::new(8);
        q.push_parked(mk_parked(&[10, 11]));
        q.push_parked(mk_parked(&[12]));
        assert_eq!(q.parked_len(), 2);

        // cancel one member of the first session: the session survives,
        // its sibling keeps its latent row
        let removed = q.remove_where(|it| it.request.id == 10);
        assert_eq!(removed.len(), 1);
        assert_eq!(q.parked_len(), 2);

        // cancel the second session entirely: it is dropped and will
        // never resume
        let removed = q.remove_where(|it| it.request.id == 12);
        assert_eq!(removed.len(), 1);
        assert_eq!(q.parked_len(), 1);

        let ps = pop_parked(&q);
        assert_eq!(ps.members.len(), 1);
        let (row, it) = &ps.members[0];
        assert_eq!(*row, 1, "surviving member keeps its original latent row");
        assert_eq!(it.request.id, 11);
    }

    #[test]
    fn pop_blocks_until_a_push_arrives() {
        let q = Arc::new(WorkQueue::new(8));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            q2.push(mk_batch(&[7]), Lane::Normal).unwrap();
        });
        let t0 = Instant::now();
        let got = pop_fresh(&q);
        assert_eq!(ids(&got), vec![7]);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        assert!(got.enqueued.elapsed() < std::time::Duration::from_secs(5));
        producer.join().unwrap();
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(WorkQueue::new(8));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(consumer.join().unwrap(), "blocked pop must observe close");
    }
}
