//! The shared pull-model work queue between the batcher and the
//! executor replica pool (ADR-002).
//!
//! One bounded, two-lane MPMC queue replaces the per-replica channels
//! the round-robin `Router` used to feed: the batcher pushes every
//! flushed batch here, and each executor pulls its next batch the
//! moment it goes idle. A replica stuck in a long calibration simply
//! stops pulling — it can no longer head-of-line-block batches a
//! sibling could serve, which was the failure mode recorded in
//! ROADMAP.md after the PR 2 review.
//!
//! Three properties the queue maintains:
//!
//! * **Bounded depth / admission control** — at most `depth` *requests*
//!   (summed over queued batches) wait at any time. A push that would
//!   exceed the bound is rejected and the whole batch handed back to
//!   the caller, which fails each request with a well-formed
//!   `overloaded:` error instead of letting latency grow without
//!   bound (the backpressure story; see docs/protocol.md). An empty
//!   queue always admits one batch regardless of its size, so a
//!   `depth` smaller than the largest supported batch can never wedge
//!   the pipeline.
//! * **Priority lane** — batches whose policy needs no cold
//!   calibration (`no-cache`, `fora`, `alternate`, `delta-dit`, and
//!   `smooth:*` keys whose curves are already cached) overtake batches
//!   that are about to pay a calibration, so cheap traffic never waits
//!   behind an expensive cold key. Within a lane, order is FIFO. The
//!   priority lane is served strictly first; under a sustained flood
//!   of priority traffic a normal-lane batch waits until the flood
//!   ebbs — bounded depth turns that starvation into admission
//!   rejections rather than unbounded queueing (tradeoff recorded in
//!   ADR-002).
//! * **Graceful drain** — [`WorkQueue::close`] stops admissions while
//!   letting executors drain everything already queued; [`WorkQueue::pop`]
//!   returns `None` only once the queue is both closed and empty.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use super::request::InFlight;

/// Which lane a batch enters the queue on. See the module docs for the
/// overtaking semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Served first: the batch's policy resolves without a cold
    /// calibration, so an idle replica can run it immediately.
    Priority,
    /// Served when the priority lane is empty: the batch will trigger
    /// (or wait on) an expensive calibration.
    Normal,
}

/// A batch travelling through the queue, stamped at admission so the
/// executor that pops it can account queue wait separately from
/// execution time ([`super::Metrics::queue_wait`]).
pub struct QueuedBatch {
    /// The flushed batch (homogeneous in [`super::BatchKey`] by
    /// construction — the batcher never mixes keys).
    pub batch: Vec<InFlight>,
    /// When [`WorkQueue::push`] admitted the batch.
    pub enqueued: Instant,
    /// The lane the batch was admitted on.
    pub lane: Lane,
}

struct State {
    prio: VecDeque<QueuedBatch>,
    normal: VecDeque<QueuedBatch>,
    /// Invariant: always equals the sum of `batch.len()` over both lanes.
    queued_requests: usize,
    open: bool,
}

/// Bounded two-lane MPMC work queue (`Mutex` + `Condvar`; no external
/// crates offline). Producers ([`WorkQueue::push`]) never block —
/// admission either succeeds or fails immediately. Consumers
/// ([`WorkQueue::pop`]) block until a batch is available or the queue
/// is closed and drained.
pub struct WorkQueue {
    depth: usize,
    state: Mutex<State>,
    cv: Condvar,
}

/// Poison recovery: the queue's internal lock is only ever held for a
/// few pointer moves (no user code runs under it), so its state is
/// always consistent even if a holder thread panicked.
fn lock(m: &Mutex<State>) -> std::sync::MutexGuard<'_, State> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl WorkQueue {
    /// Create a queue admitting at most `depth` queued requests
    /// (`depth` is clamped to ≥ 1).
    pub fn new(depth: usize) -> WorkQueue {
        WorkQueue {
            depth: depth.max(1),
            state: Mutex::new(State {
                prio: VecDeque::new(),
                normal: VecDeque::new(),
                queued_requests: 0,
                open: true,
            }),
            cv: Condvar::new(),
        }
    }

    /// The configured admission bound, in requests.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Requests currently waiting (summed over queued batches in both
    /// lanes; excludes batches already popped by an executor).
    pub fn len(&self) -> usize {
        lock(&self.state).queued_requests
    }

    /// `true` when no batch is waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a batch on `lane`, or hand it back when the queue is full
    /// (or closed) so the caller can reject each request with an error.
    /// Never blocks.
    pub fn push(&self, batch: Vec<InFlight>, lane: Lane) -> Result<(), Vec<InFlight>> {
        let mut st = lock(&self.state);
        if !st.open {
            return Err(batch);
        }
        let n = batch.len();
        // admit-if-empty: a single batch larger than `depth` must still
        // be servable, otherwise it could never run at any queue state
        if st.queued_requests > 0 && st.queued_requests + n > self.depth {
            return Err(batch);
        }
        st.queued_requests += n;
        let q = QueuedBatch { batch, enqueued: Instant::now(), lane };
        match lane {
            Lane::Priority => st.prio.push_back(q),
            Lane::Normal => st.normal.push_back(q),
        }
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Pull the next batch: priority lane first, FIFO within a lane.
    /// Blocks while the queue is open and empty; returns `None` once
    /// the queue is closed **and** fully drained (the executor's signal
    /// to exit).
    pub fn pop(&self) -> Option<QueuedBatch> {
        let mut st = lock(&self.state);
        loop {
            let next = match st.prio.pop_front() {
                Some(q) => Some(q),
                None => st.normal.pop_front(),
            };
            if let Some(q) = next {
                st.queued_requests -= q.batch.len();
                return Some(q);
            }
            if !st.open {
                return None;
            }
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }

    /// Stop admissions and wake every blocked consumer. Batches already
    /// queued remain poppable (graceful drain); once they are gone,
    /// [`WorkQueue::pop`] returns `None`. Idempotent.
    pub fn close(&self) {
        lock(&self.state).open = false;
        self.cv.notify_all();
    }

    /// Pull every queued request matching `pred` out of the queue —
    /// their admission slots free immediately and they never reach a
    /// replica — returning them so the caller can answer each one
    /// (cancellation purge, [`super::Coordinator::cancel`]). Batches
    /// left empty are dropped; FIFO order of the rest is untouched.
    pub fn remove_where(&self, pred: impl Fn(&InFlight) -> bool) -> Vec<InFlight> {
        fn take(
            lane: &mut VecDeque<QueuedBatch>,
            pred: &impl Fn(&InFlight) -> bool,
            removed: &mut Vec<InFlight>,
        ) {
            for qb in lane.iter_mut() {
                let mut i = 0;
                while i < qb.batch.len() {
                    if pred(&qb.batch[i]) {
                        removed.push(qb.batch.remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
            lane.retain(|qb| !qb.batch.is_empty());
        }
        let mut removed = Vec::new();
        let mut st = lock(&self.state);
        take(&mut st.prio, &pred, &mut removed);
        take(&mut st.normal, &pred, &mut removed);
        st.queued_requests -= removed.len();
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Policy, Request};
    use crate::model::Cond;
    use crate::solvers::SolverKind;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn mk_batch(ids: &[u64]) -> Vec<InFlight> {
        ids.iter()
            .map(|&id| {
                let (tx, rx) = channel();
                std::mem::forget(rx); // keep the reply channel alive
                InFlight::new(
                    Request {
                        id,
                        family: "image".into(),
                        cond: Cond::Label(vec![1]),
                        solver: SolverKind::Ddim,
                        steps: 4,
                        cfg_scale: 1.0,
                        seed: id,
                        policy: Policy::no_cache(),
                        compute: Default::default(),
                    },
                    tx,
                )
            })
            .collect()
    }

    fn ids(q: &QueuedBatch) -> Vec<u64> {
        q.batch.iter().map(|it| it.request.id).collect()
    }

    #[test]
    fn fifo_within_lane_priority_overtakes() {
        let q = WorkQueue::new(64);
        q.push(mk_batch(&[1]), Lane::Normal).unwrap();
        q.push(mk_batch(&[2]), Lane::Normal).unwrap();
        q.push(mk_batch(&[3]), Lane::Priority).unwrap();
        q.push(mk_batch(&[4]), Lane::Priority).unwrap();
        assert_eq!(ids(&q.pop().unwrap()), vec![3]);
        assert_eq!(ids(&q.pop().unwrap()), vec![4]);
        assert_eq!(ids(&q.pop().unwrap()), vec![1]);
        assert_eq!(ids(&q.pop().unwrap()), vec![2]);
        assert!(q.is_empty());
    }

    #[test]
    fn admission_rejects_when_full_and_hands_batch_back() {
        let q = WorkQueue::new(2);
        q.push(mk_batch(&[1, 2]), Lane::Priority).unwrap();
        assert_eq!(q.len(), 2);
        let rejected = q.push(mk_batch(&[3]), Lane::Priority).unwrap_err();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].request.id, 3); // intact, caller can reply
        assert_eq!(q.len(), 2); // rejection did not corrupt accounting
        // draining frees capacity again
        q.pop().unwrap();
        q.push(mk_batch(&[4]), Lane::Normal).unwrap();
    }

    #[test]
    fn empty_queue_admits_oversized_batch() {
        let q = WorkQueue::new(1);
        q.push(mk_batch(&[1, 2, 3]), Lane::Priority).unwrap();
        // but a second batch is over the bound until the first drains
        assert!(q.push(mk_batch(&[4]), Lane::Priority).is_err());
        assert_eq!(ids(&q.pop().unwrap()), vec![1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = WorkQueue::new(8);
        q.push(mk_batch(&[1]), Lane::Normal).unwrap();
        q.push(mk_batch(&[2]), Lane::Priority).unwrap();
        q.close();
        // pushes after close are rejected…
        assert!(q.push(mk_batch(&[3]), Lane::Priority).is_err());
        // …but queued work still drains, priority first
        assert_eq!(ids(&q.pop().unwrap()), vec![2]);
        assert_eq!(ids(&q.pop().unwrap()), vec![1]);
        assert!(q.pop().is_none());
        assert!(q.pop().is_none()); // idempotent
    }

    #[test]
    fn remove_where_frees_slots_and_drops_empty_batches() {
        let q = WorkQueue::new(4);
        q.push(mk_batch(&[1, 2]), Lane::Priority).unwrap();
        q.push(mk_batch(&[3]), Lane::Normal).unwrap();
        assert_eq!(q.len(), 3);
        // queue is full enough that another 2-request batch is rejected
        assert!(q.push(mk_batch(&[4, 5]), Lane::Normal).is_err());

        // purge one request out of the priority batch and the whole
        // normal batch — slots free immediately
        let removed = q.remove_where(|it| it.request.id == 2 || it.request.id == 3);
        assert_eq!(
            removed.iter().map(|it| it.request.id).collect::<Vec<_>>(),
            vec![2, 3]
        );
        assert_eq!(q.len(), 1);
        // freed capacity admits the batch that was rejected above
        q.push(mk_batch(&[4, 5]), Lane::Normal).unwrap();

        // the emptied normal batch is gone; the surviving priority
        // request still pops first, then the new batch
        assert_eq!(ids(&q.pop().unwrap()), vec![1]);
        assert_eq!(ids(&q.pop().unwrap()), vec![4, 5]);
        assert!(q.is_empty());
        assert!(q.remove_where(|_| true).is_empty());
    }

    #[test]
    fn pop_blocks_until_a_push_arrives() {
        let q = Arc::new(WorkQueue::new(8));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            q2.push(mk_batch(&[7]), Lane::Normal).unwrap();
        });
        let t0 = Instant::now();
        let got = q.pop().expect("batch");
        assert_eq!(ids(&got), vec![7]);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        assert!(got.enqueued.elapsed() < std::time::Duration::from_secs(5));
        producer.join().unwrap();
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(WorkQueue::new(8));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(consumer.join().unwrap(), "blocked pop must observe close");
    }
}
