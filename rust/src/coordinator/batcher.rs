//! Dynamic batching core (vLLM-style): group compatible requests, flush
//! on size or deadline, pad to the nearest AOT-compiled batch size.
//!
//! Pure data structure — the coordinator thread drives it with wall
//! clock instants, so every policy decision is unit- and property-
//! testable without threads.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::request::{BatchKey, InFlight};

/// Tunables for the grouping policy (sizes come from the manifest).
pub struct BatcherConfig {
    /// AOT-compiled batch sizes (ascending), from the manifest.
    pub supported_batches: Vec<usize>,
    /// max time the oldest request in a group may wait before flush.
    pub max_wait: Duration,
}

impl BatcherConfig {
    /// Largest batch eligible for a request group, accounting for CFG
    /// doubling (a CFG batch of b runs as an effective 2b batch).
    /// Takes the true maximum — `last()` assumed an ascending list, and
    /// an unsorted manifest would have silently capped groups at
    /// whatever size happened to be listed last.
    pub fn max_group(&self, cfg_enabled: bool) -> usize {
        let max = self.supported_batches.iter().copied().max().unwrap_or(1);
        if cfg_enabled {
            (max / 2).max(1)
        } else {
            max
        }
    }

    /// Smallest supported batch ≥ n (the padding target). `None` if n
    /// exceeds every compiled size.
    pub fn pad_target(&self, n: usize, cfg_enabled: bool) -> Option<usize> {
        let fits = |b: usize| {
            let eff = if cfg_enabled { 2 * b } else { b };
            self.supported_batches.contains(&eff)
        };
        (n..=self.max_group(cfg_enabled)).find(|&b| fits(b))
    }
}

struct Group {
    items: Vec<InFlight>,
    oldest: Instant,
}

/// Accumulates requests per compatibility key; yields flushable batches.
/// Invariant: every yielded batch is homogeneous in [`BatchKey`] and
/// never exceeds the effective max size (propcheck-locked in
/// `tests/coordinator_props.rs`).
pub struct Batcher {
    /// The grouping tunables this batcher was built with.
    pub config: BatcherConfig,
    groups: HashMap<BatchKey, Group>,
}

impl Batcher {
    /// An empty batcher with the given tunables.
    pub fn new(config: BatcherConfig) -> Batcher {
        Batcher { config, groups: HashMap::new() }
    }

    /// Requests currently buffered across all groups (not yet flushed).
    pub fn pending(&self) -> usize {
        self.groups.values().map(|g| g.items.len()).sum()
    }

    /// Enqueue; returns a full batch if the group reached max size.
    pub fn push(&mut self, item: InFlight, now: Instant) -> Option<Vec<InFlight>> {
        let key = item.request.batch_key();
        let cfg = item.request.cfg_scale != 1.0;
        let max = self.config.max_group(cfg);
        let group = self.groups.entry(key.clone()).or_insert_with(|| Group {
            items: Vec::new(),
            oldest: now,
        });
        if group.items.is_empty() {
            group.oldest = now;
        }
        group.items.push(item);
        if group.items.len() >= max {
            let g = self.groups.remove(&key).unwrap();
            return Some(g.items);
        }
        None
    }

    /// Flush every group whose oldest request exceeded max_wait.
    pub fn poll(&mut self, now: Instant) -> Vec<Vec<InFlight>> {
        let expired: Vec<BatchKey> = self
            .groups
            .iter()
            .filter(|(_, g)| now.duration_since(g.oldest) >= self.config.max_wait)
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .filter_map(|k| self.groups.remove(&k).map(|g| g.items))
            .collect()
    }

    /// Flush everything (shutdown / drain).
    pub fn drain(&mut self) -> Vec<Vec<InFlight>> {
        self.groups.drain().map(|(_, g)| g.items).collect()
    }

    /// Remove buffered (not yet flushed) requests matching `pred`,
    /// dropping groups left empty; the removed requests are returned so
    /// the caller can answer them. The coordinator uses this to shed
    /// cancelled / deadline-expired requests before they ever reach the
    /// work queue. Group flush deadlines are left untouched (a purged
    /// oldest member can only make the group flush early, never late).
    pub fn remove_where(&mut self, pred: impl Fn(&InFlight) -> bool) -> Vec<InFlight> {
        let mut removed = Vec::new();
        self.groups.retain(|_, g| {
            let mut i = 0;
            while i < g.items.len() {
                if pred(&g.items[i]) {
                    removed.push(g.items.remove(i));
                } else {
                    i += 1;
                }
            }
            !g.items.is_empty()
        });
        removed
    }

    /// Time until the next deadline-based flush, if any.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.groups
            .values()
            .map(|g| {
                self.config
                    .max_wait
                    .checked_sub(now.duration_since(g.oldest))
                    .unwrap_or(Duration::ZERO)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Policy, Request};
    use crate::model::Cond;
    use crate::solvers::SolverKind;
    use crate::util::propcheck::{forall, gen};
    use crate::util::rng::Rng;
    use std::sync::mpsc::channel;

    fn mk_inflight(family: &str, steps: usize, cfg: f32, id: u64) -> InFlight {
        let (tx, _rx) = channel();
        // keep the receiver alive long enough for tests that don't reply
        std::mem::forget(_rx);
        InFlight::new(
            Request {
                id,
                family: family.into(),
                cond: Cond::Label(vec![1]),
                solver: SolverKind::Ddim,
                steps,
                cfg_scale: cfg,
                seed: id,
                policy: Policy::no_cache(),
                compute: Default::default(),
                priority: Default::default(),
            },
            tx,
        )
    }

    #[test]
    fn priority_classes_never_share_a_batch() {
        // BatchKey carries the priority class, so the batcher cannot mix
        // an interactive request with a batch-class one — the scheduler's
        // class ordering would be meaningless inside a single batch
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        let mut int = mk_inflight("image", 10, 1.0, 1);
        let mut bat = mk_inflight("image", 10, 1.0, 2);
        int.request.priority = crate::coordinator::PriorityClass::Interactive;
        bat.request.priority = crate::coordinator::PriorityClass::Batch;
        assert_ne!(int.request.batch_key(), bat.request.batch_key());
        assert!(b.push(int, now).is_none());
        assert!(b.push(bat, now).is_none());
        let flushed = b.drain();
        assert_eq!(flushed.len(), 2, "one group per class");
        for batch in &flushed {
            assert_eq!(batch.len(), 1);
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig { supported_batches: vec![1, 2, 4, 8], max_wait: Duration::from_millis(50) }
    }

    #[test]
    fn pad_target_rounding() {
        let c = cfg();
        assert_eq!(c.pad_target(1, false), Some(1));
        assert_eq!(c.pad_target(3, false), Some(4));
        assert_eq!(c.pad_target(5, false), Some(8));
        assert_eq!(c.pad_target(9, false), None);
        // CFG halves the usable size
        assert_eq!(c.pad_target(3, true), Some(4));
        assert_eq!(c.pad_target(4, true), Some(4));
        assert_eq!(c.pad_target(5, true), None);
    }

    #[test]
    fn max_group_is_order_independent() {
        // regression: max_group read `.last()`, so an unsorted
        // supported_batches list capped every group at the last-listed
        // size (here 2) instead of the true maximum
        let c = BatcherConfig {
            supported_batches: vec![4, 8, 1, 2],
            max_wait: Duration::from_millis(50),
        };
        assert_eq!(c.max_group(false), 8);
        assert_eq!(c.max_group(true), 4);
        // and the empty list still degrades to single-request batches
        let empty = BatcherConfig { supported_batches: vec![], max_wait: Duration::from_millis(1) };
        assert_eq!(empty.max_group(false), 1);
        // pad_target keeps working against the unsorted list
        assert_eq!(c.pad_target(5, false), Some(8));
    }

    #[test]
    fn flush_on_full() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..7 {
            assert!(b.push(mk_inflight("image", 10, 1.0, i), now).is_none());
        }
        let full = b.push(mk_inflight("image", 10, 1.0, 7), now);
        assert_eq!(full.unwrap().len(), 8);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn cfg_groups_flush_at_half() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..3 {
            assert!(b.push(mk_inflight("image", 10, 1.5, i), now).is_none());
        }
        let full = b.push(mk_inflight("image", 10, 1.5, 3), now);
        assert_eq!(full.unwrap().len(), 4);
    }

    #[test]
    fn incompatible_requests_do_not_mix() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        b.push(mk_inflight("image", 10, 1.0, 0), now);
        b.push(mk_inflight("image", 20, 1.0, 1), now);
        b.push(mk_inflight("audio", 10, 1.0, 2), now);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.groups.len(), 3);
    }

    #[test]
    fn deadline_flush() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        b.push(mk_inflight("image", 10, 1.0, 0), t0);
        assert!(b.poll(t0).is_empty());
        let later = t0 + Duration::from_millis(60);
        let flushed = b.poll(later);
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        assert!(b.next_deadline(t0).is_none());
        b.push(mk_inflight("image", 10, 1.0, 0), t0);
        let d = b.next_deadline(t0 + Duration::from_millis(20)).unwrap();
        assert!(d <= Duration::from_millis(30));
    }

    #[test]
    fn remove_where_purges_buffered_requests_and_empty_groups() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..3 {
            b.push(mk_inflight("image", 10, 1.0, i), now);
        }
        b.push(mk_inflight("audio", 10, 1.0, 3), now);
        assert_eq!(b.pending(), 4);

        // purge one member of the image group and the whole audio group
        let removed = b.remove_where(|it| it.request.id >= 2);
        assert_eq!(removed.len(), 2);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.groups.len(), 1, "emptied groups must be dropped");

        // survivors still flush normally
        let flushed = b.poll(now + Duration::from_millis(60));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].len(), 2);
        assert!(b.remove_where(|_| true).is_empty());
    }

    #[test]
    fn drain_returns_everything() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..5 {
            b.push(mk_inflight("image", 10 + (i as usize % 2), 1.0, i), now);
        }
        let drained = b.drain();
        assert_eq!(drained.iter().map(|g| g.len()).sum::<usize>(), 5);
        assert_eq!(b.pending(), 0);
    }

    /// Property: under any request sequence, (a) every flushed batch is
    /// homogeneous in batch key, (b) no batch exceeds the effective max,
    /// (c) nothing is lost or duplicated.
    #[test]
    fn prop_batcher_invariants() {
        forall(
            0xBA7C4,
            60,
            |r: &mut Rng| {
                gen::vec_of(r, 1, 40, |r| {
                    (
                        r.below(3),          // family selector
                        10 + r.below(2),     // steps
                        r.below(2),          // cfg on/off
                    )
                })
            },
            |seq: &Vec<(usize, usize, usize)>| {
                let mut b = Batcher::new(cfg());
                let now = Instant::now();
                let mut seen_out = 0usize;
                let families = ["image", "audio", "video"];
                for (i, &(f, steps, use_cfg)) in seq.iter().enumerate() {
                    let item = mk_inflight(
                        families[f],
                        steps,
                        if use_cfg == 1 { 1.5 } else { 1.0 },
                        i as u64,
                    );
                    if let Some(batch) = b.push(item, now) {
                        let key = batch[0].request.batch_key();
                        let cfg_on = batch[0].request.cfg_scale != 1.0;
                        let max = b.config.max_group(cfg_on);
                        if batch.len() > max {
                            return Err(format!("batch of {} > max {max}", batch.len()));
                        }
                        for it in &batch {
                            if it.request.batch_key() != key {
                                return Err("heterogeneous batch".into());
                            }
                        }
                        seen_out += batch.len();
                    }
                }
                for batch in b.drain() {
                    let key = batch[0].request.batch_key();
                    for it in &batch {
                        if it.request.batch_key() != key {
                            return Err("heterogeneous drained batch".into());
                        }
                    }
                    seen_out += batch.len();
                }
                if seen_out != seq.len() {
                    return Err(format!("lost requests: {seen_out} != {}", seq.len()));
                }
                Ok(())
            },
        );
    }
}
