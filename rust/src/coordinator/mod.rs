//! L3 serving coordinator: dynamic batcher → shared work queue →
//! executor pool.
//!
//! Thread topology (no tokio offline; DESIGN.md §3):
//!
//! ```text
//!  clients ──submit()──► [batcher thread] ──batches──► [work queue]  ◄──pull── [executor 0]
//!                         groups by key                bounded,      ◄──pull── [executor 1]
//!                         (incl. priority              class-aware:    ...       ...
//!                         class), flushes on           interactive ▸  ◄──pull── [executor N-1]
//!                         size or deadline             parked ▸ batch            each owns its
//!                                                      + aging rule              own engine
//!                                                          ▲    │
//!                                                          └────┘ park/resume
//!                                                       (preempted sessions)
//! ```
//!
//! Batching remains the primary concurrency mechanism (as in the
//! paper's serving setting); the executor *pool* adds a second axis for
//! backends that can replicate — the reference backend runs one engine
//! per worker thread, each of which also fans its GEMM row panels over
//! the shared compute pool ([`crate::tensor::gemm`]). Backends with
//! thread-bound device handles (PJRT) transparently degrade to a pool
//! of one ([`crate::runtime::backend_supports_replicas`]).
//!
//! Between the batcher and the pool sits one bounded, class-aware
//! [`queue::WorkQueue`] (ADR-002, extended by docs/adr/007): executors
//! *pull* their next work item when free, so a replica stuck in a long
//! calibration stops pulling instead of starving a private channel.
//! Every request carries a [`PriorityClass`] (`interactive` — the
//! default — or `batch`): interactive work is always served first, and
//! an executor mid-way through a *batch*-class generation **preempts**
//! it at the next solver-step boundary when fresh interactive work is
//! waiting — the session is snapshotted
//! ([`crate::pipeline::GenSession::snapshot`]) and parked back into the
//! queue, to be resumed later on any replica bitwise-identically. A
//! count-based aging rule bounds starvation: after
//! [`CoordinatorConfig::aging_limit`] consecutive interactive serves
//! with lower-class work waiting, the oldest parked/batch item runs
//! next. Within a class, batches that need no cold calibration take
//! the priority lane and overtake ones that do; when the queue is full,
//! new batches are rejected with an `overloaded:` error rather than
//! queued without bound (`--queue-depth`, docs/protocol.md).
//! Calibration curves and resolved [`crate::cache::CachePlan`]s live in
//! one [`executor::SharedPlanStore`]; calibration locking is
//! **per-key** ([`executor::plan_shared`]), so "calibrate once per
//! configuration" holds at any pool size while a calibration of one
//! key never blocks requests for another; the lane choice for each
//! batch comes straight from the policy registry
//! ([`crate::cache::plan::registry`]) instead of re-matching an enum.
//!
//! Requests are controllable while in flight (ADR-004, [`cancel`]):
//! every submission carries a cancellation token and an optional
//! [`Deadline`]; [`Coordinator::cancel`] (or a client disconnect at
//! the server layer) stops queued work immediately — the admission
//! slot frees and the batch never reaches a replica — and stops
//! executing work at the next solver-step boundary, since executors
//! drive each batch as a step-wise [`crate::pipeline::GenSession`].
//! The same step loop emits per-step [`Progress`] events for
//! streaming clients.
#![deny(missing_docs)]

pub mod batcher;
pub mod cancel;
pub mod executor;
pub mod metrics;
pub mod queue;
pub mod request;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::Result;

use cancel::{lock_cancels, reply_dead, CancelMap, CancelRegistration, CancelToken};
use crate::obs::{Outcome, TraceHandle};

pub use batcher::{Batcher, BatcherConfig};
pub use cancel::{Deadline, DeadlinePolicy, Progress};
pub use executor::{plan_shared, ExecutorConfig, PlanKey, PlanStore, SharedPlanStore};
pub use metrics::{Histogram, Metrics};
pub use queue::{Lane, ParkedSession, QueuedBatch, WorkItem, WorkQueue};
pub use request::{BatchKey, InFlight, Policy, PriorityClass, Request, Response};

/// Everything [`Coordinator::start`] needs to bring the serving
/// pipeline up.
pub struct CoordinatorConfig {
    /// Artifact directory every executor replica opens its engine on.
    pub artifacts_dir: std::path::PathBuf,
    /// Families to preload in each replica at startup (lazy otherwise).
    pub preload: Vec<String>,
    /// AOT-compiled batch sizes requests may be padded to (ascending).
    pub supported_batches: Vec<usize>,
    /// Max time the oldest request in a batcher group may wait before a
    /// deadline flush.
    pub max_wait: Duration,
    /// Calibration samples for on-demand `smooth:*` calibration.
    pub calib_samples: usize,
    /// Seed for on-demand calibration passes.
    pub calib_seed: u64,
    /// Optional directory of pre-computed calibration curves.
    pub curves_dir: Option<std::path::PathBuf>,
    /// Executor replicas (engines) to run; clamped to 1 when the
    /// selected backend cannot replicate (PJRT). Default: the
    /// `SMOOTHCACHE_WORKERS` environment variable, else 2.
    pub workers: usize,
    /// Work-queue admission bound, in *requests* waiting for an
    /// executor (`--queue-depth`): pushes beyond it are rejected with
    /// an `overloaded:` error. Default: the `SMOOTHCACHE_QUEUE_DEPTH`
    /// environment variable, else 256.
    pub queue_depth: usize,
    /// Anti-starvation aging limit (docs/adr/007): after this many
    /// consecutive interactive serves while batch-class or parked work
    /// waits, the scheduler serves the oldest lower-class item next.
    /// Clamped to ≥ 1; default 4.
    pub aging_limit: usize,
}

impl CoordinatorConfig {
    /// Defaults for serving out of `artifacts_dir` (see field docs).
    pub fn new(artifacts_dir: std::path::PathBuf) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir,
            preload: vec![],
            supported_batches: vec![1, 2, 4, 8],
            max_wait: Duration::from_millis(20),
            calib_samples: 4,
            calib_seed: 0xCA11B,
            curves_dir: None,
            workers: default_workers(),
            queue_depth: default_queue_depth(),
            aging_limit: 4,
        }
    }

    /// Builder-style override of [`CoordinatorConfig::workers`]
    /// (clamped to ≥ 1).
    pub fn with_workers(mut self, n: usize) -> CoordinatorConfig {
        self.workers = n.max(1);
        self
    }

    /// Builder-style override of [`CoordinatorConfig::queue_depth`]
    /// (clamped to ≥ 1).
    pub fn with_queue_depth(mut self, depth: usize) -> CoordinatorConfig {
        self.queue_depth = depth.max(1);
        self
    }

    /// Builder-style override of [`CoordinatorConfig::aging_limit`]
    /// (clamped to ≥ 1).
    pub fn with_aging_limit(mut self, limit: usize) -> CoordinatorConfig {
        self.aging_limit = limit.max(1);
        self
    }
}

fn default_workers() -> usize {
    std::env::var("SMOOTHCACHE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(2)
}

fn default_queue_depth() -> usize {
    std::env::var("SMOOTHCACHE_QUEUE_DEPTH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(256)
}

/// Options a submission may carry beyond the [`Request`] itself:
/// a per-step progress stream (streaming clients) and a latency
/// deadline. `SubmitOpts::default()` is a plain blocking submission.
#[derive(Debug, Default)]
pub struct SubmitOpts {
    /// Receive one [`Progress`] event per solver step while the
    /// request's batch executes.
    pub progress: Option<Sender<Progress>>,
    /// Optional latency budget (see [`Deadline`]).
    pub deadline: Option<Deadline>,
    /// Trace context for this request (docs/adr/009). The default
    /// (disabled) handle makes [`Coordinator::submit_opts`] open a
    /// fresh one at the active [`crate::obs::level`]; the server passes
    /// a pre-opened handle here so wire ingress events land on the same
    /// timeline.
    pub trace: TraceHandle,
}

/// Handle returned by [`Coordinator::submit_opts`]: the assigned
/// request id — usable with [`Coordinator::cancel`] while the request
/// is in flight — plus the single-use reply channel.
pub struct Ticket {
    /// The coordinator-assigned (or caller-chosen, if nonzero) id.
    pub id: u64,
    /// Exactly one message ever arrives here: the [`Response`], an
    /// execution error, an `overloaded:` rejection, a `cancelled:`
    /// abort or a `deadline:` rejection.
    pub reply: Receiver<Result<Response>>,
}

/// Handle to a running coordinator. Dropping it shuts the pipeline down
/// (in-flight requests drain first).
pub struct Coordinator {
    tx: Option<Sender<InFlight>>,
    queue: Arc<WorkQueue>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    cancels: CancelMap,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    executor_handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the batcher thread, the shared work queue, and the
    /// executor replica pool; returns once every thread is running.
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let (req_tx, req_rx) = channel::<InFlight>();

        // executor replica pool (PJRT degrades to a pool of one)
        let manifest_on_disk = config.artifacts_dir.join("manifest.json").exists();
        let replicas = if crate::runtime::backend_supports_replicas(
            &config.artifacts_dir,
            manifest_on_disk,
        ) {
            config.workers.max(1)
        } else {
            1
        };
        metrics.executor_replicas.store(replicas as u64, Ordering::Relaxed);

        let ecfg = ExecutorConfig {
            artifacts_dir: config.artifacts_dir,
            preload: config.preload,
            calib_samples: config.calib_samples,
            calib_seed: config.calib_seed,
            curves_dir: config.curves_dir,
        };
        let store: SharedPlanStore = Arc::new(Mutex::new(PlanStore::new(
            ecfg.calib_samples,
            ecfg.calib_seed,
            ecfg.curves_dir.clone(),
        )));
        let queue = Arc::new(WorkQueue::with_aging(config.queue_depth, config.aging_limit));
        let live = Arc::new(AtomicUsize::new(replicas));
        let mut executor_handles = Vec::with_capacity(replicas);
        for w in 0..replicas {
            let cfg_w = ecfg.clone();
            let supported = config.supported_batches.clone();
            let q2 = Arc::clone(&queue);
            let live2 = Arc::clone(&live);
            let m2 = Arc::clone(&metrics);
            let store_w = Arc::clone(&store);
            let handle = std::thread::Builder::new()
                .name(format!("smoothcache-executor-{w}"))
                .spawn(move || {
                    executor::run_executor(w, cfg_w, supported, q2, live2, m2, store_w)
                })
                .map_err(|e| crate::err!("spawn executor {w}: {e}"))?;
            executor_handles.push(handle);
        }

        let bcfg = BatcherConfig {
            supported_batches: config.supported_batches.clone(),
            max_wait: config.max_wait,
        };
        let q_batcher = Arc::clone(&queue);
        let store_batcher = Arc::clone(&store);
        let m_batcher = Arc::clone(&metrics);
        let batcher_handle = std::thread::Builder::new()
            .name("smoothcache-batcher".into())
            .spawn(move || run_batcher(bcfg, req_rx, q_batcher, store_batcher, m_batcher))
            .map_err(|e| crate::err!("spawn batcher: {e}"))?;

        Ok(Coordinator {
            tx: Some(req_tx),
            queue,
            metrics,
            next_id: AtomicU64::new(1),
            cancels: CancelMap::default(),
            batcher_handle: Some(batcher_handle),
            executor_handles,
        })
    }

    /// The coordinator's counters (live; shared with every thread).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Requests currently waiting in the shared work queue.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Preempted sessions currently parked in the work queue.
    pub fn parked_len(&self) -> usize {
        self.queue.parked_len()
    }

    /// Submit a request; returns the reply channel immediately. The
    /// reply is either a [`Response`], an execution error, or — when
    /// the work queue is at `--queue-depth` — an admission-control
    /// rejection whose message starts with `overloaded:`.
    pub fn submit(&self, request: Request) -> Receiver<Result<Response>> {
        self.submit_opts(request, SubmitOpts::default()).reply
    }

    /// Submit with [`SubmitOpts`] (progress stream, deadline); the
    /// returned [`Ticket`] carries the assigned id, which
    /// [`Coordinator::cancel`] accepts while the request is in flight.
    pub fn submit_opts(&self, mut request: Request, opts: SubmitOpts) -> Ticket {
        if request.id == 0 {
            request.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let id = request.id;
        Metrics::inc(&self.metrics.requests_submitted);
        let (tx, rx) = channel();
        let token = CancelToken::new();
        let registration = CancelRegistration::register(&self.cancels, id, token.clone());
        let trace = if opts.trace.is_active() { opts.trace } else { TraceHandle::start() };
        if trace.is_active() {
            trace.set_meta(id, &format!("{}/{}", request.family, request.policy.wire()));
            trace.event("submit", id, 0, 0, f64::NAN);
        }
        let item = InFlight {
            request,
            submitted: Instant::now(),
            reply: tx,
            cancel: token,
            deadline: opts.deadline,
            progress: opts.progress,
            trace,
            registration: Some(registration),
        };
        if let Some(q) = &self.tx {
            // a send error means shutdown; the caller sees a disconnect
            let _ = q.send(item);
        }
        Ticket { id, reply: rx }
    }

    /// Submit and wait.
    pub fn generate_blocking(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        rx.recv().map_err(|_| crate::err!("coordinator shut down"))?
    }

    /// Cooperatively cancel an in-flight request by id. Returns `true`
    /// when the id was known (submitted and not yet answered); the
    /// request's reply channel still receives exactly one message — a
    /// `cancelled:` error, or the finished [`Response`] if it won the
    /// race. A request still waiting in the shared work queue is pulled
    /// out *now*: its admission slot frees immediately and it never
    /// reaches a replica; one inside a **parked** session is purged the
    /// same way — a parked session whose members are all cancelled is
    /// dropped on the spot and never resumes; one buffered in the
    /// batcher is shed at its group's next flush; one executing stops
    /// at the next solver-step boundary (see
    /// [`cancel`](crate::coordinator::cancel)).
    pub fn cancel(&self, id: u64) -> bool {
        let token = lock_cancels(&self.cancels).get(&id).cloned();
        let Some(token) = token else {
            return false;
        };
        token.cancel();
        // purge by token identity, not by id: with duplicate
        // caller-chosen ids only the registered (latest) request was
        // cancelled, and an unrelated same-id request must stay queued
        let removed = self.queue.remove_where(|it| it.cancel.same(&token));
        if !removed.is_empty() {
            Metrics::set(&self.metrics.queue_depth, self.queue.len() as u64);
            Metrics::set(&self.metrics.parked_sessions, self.queue.parked_len() as u64);
            for it in removed {
                reply_dead(&self.metrics, it);
            }
        }
        true
    }

    /// Drain and stop the batcher and every executor replica.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join(); // drains its groups into the queue, then closes it
        }
        // Defensive: if the batcher thread died without closing the
        // queue, close it here so executor joins cannot hang.
        self.queue.close();
        for h in self.executor_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

/// Pick the work-queue lane for a flushed batch: priority for every
/// policy that resolves without a cold calibration, normal for
/// curve-needing keys that still need one. The calibration-free check
/// is the policy registry's lane hint
/// ([`request::Policy::needs_curves`]) — no per-policy enum matching.
/// For curve-needing policies this uses `try_lock` on the plan store:
/// if a calibration currently holds the lock we cannot cheaply tell
/// whether *this* key is hot, and conservatively treat it as cold —
/// the batcher must never block behind a calibration, that is the
/// exact head-of-line failure the queue exists to prevent.
fn lane_for(store: &SharedPlanStore, request: &Request) -> Lane {
    if !request.policy.needs_curves() {
        return Lane::Priority;
    }
    let hot = match store.try_lock() {
        Ok(s) => s.has_curves(&request.family, request.solver, request.steps),
        Err(std::sync::TryLockError::Poisoned(p)) => {
            p.into_inner()
                .has_curves(&request.family, request.solver, request.steps)
        }
        Err(std::sync::TryLockError::WouldBlock) => false,
    };
    if hot {
        Lane::Priority
    } else {
        Lane::Normal
    }
}

/// Batcher thread: pull requests, group, flush on size or deadline,
/// push each flushed batch onto the shared work queue (rejecting every
/// request of a batch the queue cannot admit). On channel disconnect it
/// drains the remaining groups into the queue and closes it, which in
/// turn lets the executor pool drain and exit.
fn run_batcher(
    config: BatcherConfig,
    rx: Receiver<InFlight>,
    queue: Arc<WorkQueue>,
    store: SharedPlanStore,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(config);
    let dispatch = |batch: Vec<InFlight>| {
        // shed members that died while buffered (cancelled requests,
        // expired reject-late deadlines) — they are answered here and
        // never consume queue admission
        let (batch, dead): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|it| !it.dead_on_arrival());
        for it in dead {
            reply_dead(&metrics, it);
        }
        if batch.is_empty() {
            return;
        }
        let lane = lane_for(&store, &batch[0].request);
        if batch.iter().any(|it| it.trace.is_active()) {
            // batcher group formation: group size + queue depth at push
            let depth = queue.len() as u64;
            let group = batch.len() as u64;
            for it in &batch {
                it.trace.event("queue_push", depth, group, 0, f64::NAN);
            }
        }
        match queue.push(batch, lane) {
            Ok(()) => {
                let depth = queue.len() as u64;
                Metrics::set(&metrics.queue_depth, depth);
                Metrics::raise(&metrics.queue_peak_depth, depth);
            }
            Err(rejected) => {
                Metrics::add(&metrics.queue_rejections, rejected.len() as u64);
                let bound = queue.depth();
                for it in rejected {
                    it.trace.event("reject", bound as u64, 0, 0, f64::NAN);
                    // seal before replying so a client reacting to the
                    // rejection finds the entry in a `dump`
                    let msg = crate::err!(
                        "overloaded: work queue full ({bound} requests); retry later{}",
                        it.trace.err_tag()
                    );
                    it.trace.finish(Outcome::Overloaded);
                    let _ = it.reply.send(Err(msg));
                }
            }
        }
    };
    loop {
        // purge buffered requests that died while waiting in a group —
        // answered promptly (within one recv timeout) instead of riding
        // along until their group's flush deadline
        for it in batcher.remove_where(|it| it.dead_on_arrival()) {
            reply_dead(&metrics, it);
        }
        let now = Instant::now();
        let timeout = batcher.next_deadline(now).unwrap_or(Duration::from_millis(100));
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                let now = Instant::now();
                if let Some(batch) = batcher.push(item, now) {
                    dispatch(batch);
                }
                for batch in batcher.poll(now) {
                    dispatch(batch);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                for batch in batcher.poll(Instant::now()) {
                    dispatch(batch);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // graceful drain: flush remaining groups, then close the
                // queue so executors drain it and exit
                for batch in batcher.drain() {
                    dispatch(batch);
                }
                queue.close();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Cond;
    use crate::solvers::SolverKind;

    fn req(policy: Policy) -> Request {
        Request {
            id: 1,
            family: "image".into(),
            cond: Cond::Label(vec![1]),
            solver: SolverKind::Ddim,
            steps: 8,
            cfg_scale: 1.0,
            seed: 1,
            policy,
            compute: Default::default(),
            priority: PriorityClass::default(),
        }
    }

    #[test]
    fn lane_for_routes_calibration_free_policies_to_priority() {
        let store: SharedPlanStore = Arc::new(Mutex::new(PlanStore::new(2, 7, None)));
        for p in [
            Policy::no_cache(),
            Policy::fora(2),
            Policy::alternate(),
            Policy::delta_dit(2),
            Policy::drift(0.3), // dynamic policies never calibrate
        ] {
            assert_eq!(lane_for(&store, &req(p)), Lane::Priority);
        }
        // cold curve-needing keys wait in the normal lane
        assert_eq!(lane_for(&store, &req(Policy::smooth(0.2))), Lane::Normal);
        assert_eq!(lane_for(&store, &req(Policy::smooth_per_site(0.2))), Lane::Normal);
    }

    #[test]
    fn lane_for_is_conservative_while_store_is_locked() {
        let store: SharedPlanStore = Arc::new(Mutex::new(PlanStore::new(2, 7, None)));
        let guard = store.lock().unwrap(); // a "calibration in flight"
        assert_eq!(lane_for(&store, &req(Policy::smooth(0.2))), Lane::Normal);
        // lock never blocks lane selection for calibration-free policies
        assert_eq!(lane_for(&store, &req(Policy::no_cache())), Lane::Priority);
        drop(guard);
    }
}
