//! L3 serving coordinator: router → dynamic batcher → executor pool.
//!
//! Thread topology (no tokio offline; DESIGN.md §3):
//!
//! ```text
//!  clients ──submit()──► [batcher thread] ──batches──► [executor 0]
//!                         groups by key,      │         [executor 1]
//!                         flushes on size     │  ...      ...
//!                         or deadline         └──────► [executor N-1]
//!                         dispatches batches            each owns its own
//!                         round-robin                   engine (backend
//!                                                       replica); all share
//!                                                       one schedule store
//! ```
//!
//! Batching remains the primary concurrency mechanism (as in the
//! paper's serving setting); the executor *pool* adds a second axis for
//! backends that can replicate — the reference backend runs one engine
//! per worker thread, each of which also fans its GEMM row panels over
//! the shared compute pool ([`crate::tensor::gemm`]). Backends with
//! thread-bound device handles (PJRT) transparently degrade to a pool
//! of one ([`crate::runtime::backend_supports_replicas`]). Calibration
//! state lives in one [`executor::SharedScheduleStore`] behind an
//! `Arc<Mutex>`, so "calibrate once per configuration" holds at any
//! pool size.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod request;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::Result;

pub use batcher::{Batcher, BatcherConfig};
pub use executor::{ExecutorConfig, ScheduleStore, SharedScheduleStore};
pub use metrics::{Histogram, Metrics};
pub use request::{BatchKey, InFlight, Policy, Request, Response};

pub struct CoordinatorConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub preload: Vec<String>,
    pub supported_batches: Vec<usize>,
    pub max_wait: Duration,
    pub calib_samples: usize,
    pub calib_seed: u64,
    pub curves_dir: Option<std::path::PathBuf>,
    /// Executor replicas (engines) to run; clamped to 1 when the
    /// selected backend cannot replicate (PJRT). Default: the
    /// `SMOOTHCACHE_WORKERS` environment variable, else 2.
    pub workers: usize,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: std::path::PathBuf) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir,
            preload: vec![],
            supported_batches: vec![1, 2, 4, 8],
            max_wait: Duration::from_millis(20),
            calib_samples: 4,
            calib_seed: 0xCA11B,
            curves_dir: None,
            workers: default_workers(),
        }
    }

    pub fn with_workers(mut self, n: usize) -> CoordinatorConfig {
        self.workers = n.max(1);
        self
    }
}

fn default_workers() -> usize {
    std::env::var("SMOOTHCACHE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(2)
}

/// Handle to a running coordinator. Dropping it shuts the pipeline down
/// (in-flight requests drain first).
pub struct Coordinator {
    tx: Option<Sender<InFlight>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    executor_handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let (req_tx, req_rx) = channel::<InFlight>();

        // executor replica pool (PJRT degrades to a pool of one)
        let manifest_on_disk = config.artifacts_dir.join("manifest.json").exists();
        let replicas = if crate::runtime::backend_supports_replicas(
            &config.artifacts_dir,
            manifest_on_disk,
        ) {
            config.workers.max(1)
        } else {
            1
        };
        metrics.executor_replicas.store(replicas as u64, Ordering::Relaxed);

        let ecfg = ExecutorConfig {
            artifacts_dir: config.artifacts_dir,
            preload: config.preload,
            calib_samples: config.calib_samples,
            calib_seed: config.calib_seed,
            curves_dir: config.curves_dir,
        };
        let store: SharedScheduleStore = Arc::new(Mutex::new(ScheduleStore::new(
            ecfg.calib_samples,
            ecfg.calib_seed,
            ecfg.curves_dir.clone(),
        )));
        let mut batch_txs = Vec::with_capacity(replicas);
        let mut executor_handles = Vec::with_capacity(replicas);
        for w in 0..replicas {
            let (batch_tx, batch_rx) = channel::<Vec<InFlight>>();
            batch_txs.push(batch_tx);
            let cfg_w = ecfg.clone();
            let supported = config.supported_batches.clone();
            let m2 = Arc::clone(&metrics);
            let store_w = Arc::clone(&store);
            let handle = std::thread::Builder::new()
                .name(format!("smoothcache-executor-{w}"))
                .spawn(move || executor::run_executor(w, cfg_w, supported, batch_rx, m2, store_w))
                .map_err(|e| crate::err!("spawn executor {w}: {e}"))?;
            executor_handles.push(handle);
        }

        let bcfg = BatcherConfig {
            supported_batches: config.supported_batches.clone(),
            max_wait: config.max_wait,
        };
        let batcher_handle = std::thread::Builder::new()
            .name("smoothcache-batcher".into())
            .spawn(move || run_batcher(bcfg, req_rx, batch_txs))
            .map_err(|e| crate::err!("spawn batcher: {e}"))?;

        Ok(Coordinator {
            tx: Some(req_tx),
            metrics,
            next_id: AtomicU64::new(1),
            batcher_handle: Some(batcher_handle),
            executor_handles,
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Submit a request; returns the reply channel immediately.
    pub fn submit(&self, mut request: Request) -> Receiver<Result<Response>> {
        if request.id == 0 {
            request.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        Metrics::inc(&self.metrics.requests_submitted);
        let (tx, rx) = channel();
        let item = InFlight { request, submitted: Instant::now(), reply: tx };
        if let Some(q) = &self.tx {
            // a send error means shutdown; the caller sees a disconnect
            let _ = q.send(item);
        }
        rx
    }

    /// Submit and wait.
    pub fn generate_blocking(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        rx.recv().map_err(|_| crate::err!("coordinator shut down"))?
    }

    /// Drain and stop the batcher and every executor replica.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join(); // closes every executor channel on exit
        }
        for h in self.executor_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

/// Round-robin router over the executor pool. Each flushed batch (one
/// [`BatchKey`] by construction) takes the next replica in rotation, so
/// even a workload with a *single* key — the common production shape —
/// keeps every replica busy once multiple batches are in flight.
/// Replica choice never affects results (replicas are identical
/// engines over the shared schedule store), so no key affinity is
/// needed, and the router carries no per-key state to bound.
///
/// Known tradeoff: rotation into per-replica channels can queue a batch
/// behind a replica that is busy (e.g. mid-calibration) while a sibling
/// idles. A shared work queue (`Mutex<Receiver>`, as `ThreadPool` uses)
/// would dispatch load-aware; tracked in ROADMAP.md.
struct Router {
    next: usize,
    n: usize,
}

impl Router {
    fn new(n: usize) -> Router {
        Router { next: 0, n: n.max(1) }
    }

    fn route(&mut self) -> usize {
        let idx = self.next % self.n;
        self.next += 1;
        idx
    }
}

/// Batcher thread: pull requests, group, flush on size or deadline,
/// dispatch each flushed batch to the next executor replica in rotation.
fn run_batcher(config: BatcherConfig, rx: Receiver<InFlight>, txs: Vec<Sender<Vec<InFlight>>>) {
    let mut batcher = Batcher::new(config);
    let mut router = Router::new(txs.len());
    let dispatch = |router: &mut Router, batch: Vec<InFlight>| -> bool {
        txs[router.route()].send(batch).is_ok()
    };
    loop {
        let now = Instant::now();
        let timeout = batcher.next_deadline(now).unwrap_or(Duration::from_millis(100));
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                let now = Instant::now();
                if let Some(batch) = batcher.push(item, now) {
                    if !dispatch(&mut router, batch) {
                        return;
                    }
                }
                for batch in batcher.poll(now) {
                    if !dispatch(&mut router, batch) {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                for batch in batcher.poll(Instant::now()) {
                    if !dispatch(&mut router, batch) {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // drain remaining groups, then stop
                for batch in batcher.drain() {
                    if !dispatch(&mut router, batch) {
                        return;
                    }
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_rotates_across_replicas() {
        let mut r = Router::new(3);
        // consecutive batches spread over the whole pool, then wrap —
        // including for a single-key workload
        assert_eq!(
            (0..7).map(|_| r.route()).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2, 0]
        );
    }

    #[test]
    fn router_with_one_replica_routes_everything_to_it() {
        let mut r = Router::new(1);
        for _ in 0..4 {
            assert_eq!(r.route(), 0);
        }
    }
}
