//! L3 serving coordinator: router → dynamic batcher → executor.
//!
//! Thread topology (no tokio offline; DESIGN.md §3):
//!
//! ```text
//!  clients ──submit()──► [batcher thread] ──batches──► [executor thread]
//!                         groups by key,                owns the engine
//!                         flushes on size                (backend) + the
//!                         or deadline                    schedule store
//! ```
//!
//! The executor is intentionally single-threaded: backend handles may
//! not be `Send` (PJRT), and a single CPU device gains nothing from
//! concurrent executions — batching is the concurrency mechanism,
//! exactly as in the paper's serving setting.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod request;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::error::Result;

pub use batcher::{Batcher, BatcherConfig};
pub use executor::{ExecutorConfig, ScheduleStore};
pub use metrics::{Histogram, Metrics};
pub use request::{BatchKey, InFlight, Policy, Request, Response};

pub struct CoordinatorConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub preload: Vec<String>,
    pub supported_batches: Vec<usize>,
    pub max_wait: Duration,
    pub calib_samples: usize,
    pub calib_seed: u64,
    pub curves_dir: Option<std::path::PathBuf>,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: std::path::PathBuf) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir,
            preload: vec![],
            supported_batches: vec![1, 2, 4, 8],
            max_wait: Duration::from_millis(20),
            calib_samples: 4,
            calib_seed: 0xCA11B,
            curves_dir: None,
        }
    }
}

/// Handle to a running coordinator. Dropping it shuts the pipeline down
/// (in-flight requests drain first).
pub struct Coordinator {
    tx: Option<Sender<InFlight>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    executor_handle: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let (req_tx, req_rx) = channel::<InFlight>();
        let (batch_tx, batch_rx) = channel::<Vec<InFlight>>();

        let bcfg = BatcherConfig {
            supported_batches: config.supported_batches.clone(),
            max_wait: config.max_wait,
        };
        let batcher_handle = std::thread::Builder::new()
            .name("smoothcache-batcher".into())
            .spawn(move || run_batcher(bcfg, req_rx, batch_tx))
            .map_err(|e| crate::err!("spawn batcher: {e}"))?;

        let ecfg = ExecutorConfig {
            artifacts_dir: config.artifacts_dir,
            preload: config.preload,
            calib_samples: config.calib_samples,
            calib_seed: config.calib_seed,
            curves_dir: config.curves_dir,
        };
        let supported = config.supported_batches;
        let m2 = Arc::clone(&metrics);
        let executor_handle = std::thread::Builder::new()
            .name("smoothcache-executor".into())
            .spawn(move || executor::run_executor(ecfg, supported, batch_rx, m2))
            .map_err(|e| crate::err!("spawn executor: {e}"))?;

        Ok(Coordinator {
            tx: Some(req_tx),
            metrics,
            next_id: AtomicU64::new(1),
            batcher_handle: Some(batcher_handle),
            executor_handle: Some(executor_handle),
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Submit a request; returns the reply channel immediately.
    pub fn submit(&self, mut request: Request) -> Receiver<Result<Response>> {
        if request.id == 0 {
            request.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        Metrics::inc(&self.metrics.requests_submitted);
        let (tx, rx) = channel();
        let item = InFlight { request, submitted: Instant::now(), reply: tx };
        if let Some(q) = &self.tx {
            // a send error means shutdown; the caller sees a disconnect
            let _ = q.send(item);
        }
        rx
    }

    /// Submit and wait.
    pub fn generate_blocking(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        rx.recv().map_err(|_| crate::err!("coordinator shut down"))?
    }

    /// Drain and stop both threads.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.executor_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

/// Batcher thread: pull requests, group, flush on size or deadline.
fn run_batcher(config: BatcherConfig, rx: Receiver<InFlight>, tx: Sender<Vec<InFlight>>) {
    let mut batcher = Batcher::new(config);
    loop {
        let now = Instant::now();
        let timeout = batcher.next_deadline(now).unwrap_or(Duration::from_millis(100));
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                let now = Instant::now();
                if let Some(batch) = batcher.push(item, now) {
                    if tx.send(batch).is_err() {
                        return;
                    }
                }
                for batch in batcher.poll(now) {
                    if tx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                for batch in batcher.poll(Instant::now()) {
                    if tx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // drain remaining groups, then stop
                for batch in batcher.drain() {
                    if tx.send(batch).is_err() {
                        return;
                    }
                }
                return;
            }
        }
    }
}
