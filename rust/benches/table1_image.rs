//! Table 1 reproduction: DiT image family under DDIM at 30/50/70 steps.
//! Rows: No-Cache, FORA(n=2,3), L2C-proxy (alternate), SmoothCache at
//! alphas matched to FORA's compute (plus a low-alpha point), sorted by
//! GMACs like the paper (which reports TMACs at DiT-XL scale).
//!
//! Quality: FFD (FID substitute), sFFD (second feature seed, sFID
//! substitute), IS-proxy — all against the blob-corpus reference set
//! (DESIGN.md section 3). Mean ± std over trials.
//!
//! SMOOTHCACHE_BENCH_FAST=1 trims steps/samples/trials; `--smoke`
//! shrinks further to CI scale; `--json OUT` writes the
//! machine-readable report for the first step count (docs/benchmarks.md).

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef, Schedule};
use smoothcache::experiments::{eval_conds, fmt_pm, generate_set, image_corpus, mean_std, EvalConfig};
use smoothcache::macs::{as_gmacs, generation_macs};
use smoothcache::model::Engine;
use smoothcache::quality::{ffd, is_proxy, lpips_proxy, psnr, FeatureExtractor};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    // `--threads N` pins the GEMM pool per evaluation (0 = auto)
    let threads = args.usize("threads", 0)?;
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();

    let (steps_list, n_samples, trials, calib_samples) = if smoke {
        (vec![4usize], 4usize, 1usize, 1usize)
    } else if fast_mode() {
        (vec![10], 16, 1, 2)
    } else {
        (vec![50, 30], 24, 2, 10)
    };

    let mut report = BenchReport::new("table1_image");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", steps_list[0]);
    report.meta("samples", n_samples);
    report.meta("trials", trials);
    report.meta("threads", threads);
    report.meta("smoke", smoke);
    report.run_meta(0);

    let fx = FeatureExtractor::new(0xF1D, 12);
    let fx_s = FeatureExtractor::new(0x5F1D, 12); // sFID-analog seed
    let (corpus, _labels) = image_corpus(128, 0xC0FFEE);

    let mut table = Table::new(&[
        "Schedule", "Steps", "FFD (dn)", "sFFD (dn)", "IS-proxy (up)", "LPIPS-drift (dn)",
        "PSNR-drift (up)", "GMACs", "Latency (s)", "skip%",
    ]);

    for &steps in &steps_list {
        eprintln!("[table1] calibrating ddim-{steps} ...");
        let cc = CalibrationConfig {
            num_samples: calib_samples,
            ..CalibrationConfig::new(SolverKind::Ddim, steps)
        };
        let curves = calibrate(&engine, "image", &cc)?;

        // warm up batch-4 executables so the first roster row's latency
        // column is not polluted by one-time PJRT compiles
        {
            let mut ec = EvalConfig::new("image", SolverKind::Ddim, 2).with_threads(threads);
            ec.n_samples = 4;
            ec.cfg_scale = 1.5;
            let conds = eval_conds(&fm, 4, 1);
            let warm_plan = CachePlan::no_cache(2, &sites);
            let _ = generate_set(&engine, &ec, &conds, PlanRef::Plan(&warm_plan))?;
        }

        // schedule roster for this step count; the slug is the stable
        // metric key (keyed by the *target* skip fraction, not the
        // calibrated alpha, so report names survive recalibration)
        let mut roster: Vec<(&'static str, String, Schedule)> = vec![
            ("no_cache", "No Cache".into(), Schedule::no_cache(steps, &bts)),
            ("fora2", "FORA (n=2)".into(), Schedule::fora(steps, &bts, 2)),
            ("fora3", "FORA (n=3)".into(), Schedule::fora(steps, &bts, 3)),
            ("l2c", "L2C-proxy".into(), Schedule::alternate(steps, &bts)),
        ];
        // Ours at compute matched to FORA n=2 / n=3, plus a conservative point
        for (slug, target) in [("ours_s50", 0.5), ("ours_s67", 2.0 / 3.0), ("ours_s20", 0.2)] {
            let (alpha, s) = curves.alpha_for_skip_fraction(target, &bts);
            roster.push((slug, format!("Ours (a={alpha:.3})"), s));
        }

        // per-trial paired no-cache reference sets (for the drift columns:
        // LPIPS/PSNR vs the non-cached generations, the paper's Table-2
        // protocol applied to Table 1 as the discriminating signal)
        let mut refs: Vec<(EvalConfig, Vec<smoothcache::model::Cond>, smoothcache::tensor::Tensor, smoothcache::experiments::EvalStats)> = Vec::new();
        for trial in 0..trials {
            let mut ec = EvalConfig::new("image", SolverKind::Ddim, steps).with_threads(threads);
            ec.n_samples = n_samples;
            ec.cfg_scale = 1.5;
            ec.base_seed = 9000 + trial as u64 * 1000;
            let conds = eval_conds(&fm, ec.n_samples, 777 + trial as u64);
            let no_cache = CachePlan::no_cache(steps, &sites);
            let (set, stats) = generate_set(&engine, &ec, &conds, PlanRef::Plan(&no_cache))?;
            refs.push((ec, conds, set, stats));
        }

        let emit_metrics = steps == steps_list[0] && json_out.is_some();
        let mut rows: Vec<(f64, Vec<String>)> = Vec::new();
        for (slug, name, schedule) in &roster {
            schedule.validate().unwrap();
            let plan = CachePlan::from_grouped(schedule, &sites)?;
            let gmacs = as_gmacs(generation_macs(&fm, schedule, true)); // CFG doubles
            let mut ffds = Vec::new();
            let mut sffds = Vec::new();
            let mut iss = Vec::new();
            let mut lats = Vec::new();
            let mut drifts = Vec::new();
            let mut psnrs = Vec::new();
            for (ec, conds, ref_set, ref_stats) in &refs {
                let (set, stats) = if schedule.skip_fraction() == 0.0 {
                    (ref_set.clone(), ref_stats.clone())
                } else {
                    generate_set(&engine, ec, conds, PlanRef::Plan(&plan))?
                };
                ffds.push(ffd(&fx, &corpus, &set));
                sffds.push(ffd(&fx_s, &corpus, &set));
                iss.push(is_proxy(&fx, &set, 10));
                lats.push(stats.per_sample_seconds);
                if schedule.skip_fraction() > 0.0 {
                    drifts.push(lpips_proxy(&fx, ref_set, &set));
                    psnrs.push(psnr(ref_set, &set));
                }
            }
            let (fm_, fs_) = mean_std(&ffds);
            let (sm, ss) = mean_std(&sffds);
            let (im, is_) = mean_std(&iss);
            let (lm, _) = mean_std(&lats);
            if emit_metrics {
                report.metric_tol(&format!("{slug}/ffd"), fm_, "score", false, 2.0)?;
                report.metric_tol(&format!("{slug}/sffd"), sm, "score", false, 2.0)?;
                report.metric_tol(&format!("{slug}/is_proxy"), im, "score", true, 2.0)?;
                report.metric_tol(&format!("{slug}/gmacs"), gmacs, "GMACs", false, 0.1)?;
                report.metric_tol(&format!("{slug}/latency_s"), lm, "s", false, 100.0)?;
                report.metric_tol(
                    &format!("{slug}/skip_pct"),
                    schedule.skip_fraction() * 100.0,
                    "%",
                    true,
                    1.0,
                )?;
                if !drifts.is_empty() {
                    report.metric_tol(&format!("{slug}/lpips"), mean_std(&drifts).0, "score", false, 5.0)?;
                    let p = mean_std(&psnrs).0;
                    // psnr is +inf for bitwise-identical sets; a report
                    // only holds finite values
                    if p.is_finite() {
                        report.metric_tol(&format!("{slug}/psnr"), p, "dB", true, 5.0)?;
                    }
                }
            }
            let drift_cell = if drifts.is_empty() {
                "-".to_string()
            } else {
                let (m, s) = mean_std(&drifts);
                fmt_pm(m, s, 4)
            };
            let psnr_cell = if psnrs.is_empty() {
                "-".to_string()
            } else {
                let (m, s) = mean_std(&psnrs);
                fmt_pm(m, s, 1)
            };
            rows.push((
                gmacs,
                vec![
                    name.clone(),
                    steps.to_string(),
                    fmt_pm(fm_, fs_, 3),
                    fmt_pm(sm, ss, 3),
                    fmt_pm(im, is_, 2),
                    drift_cell,
                    psnr_cell,
                    format!("{gmacs:.2}"),
                    format!("{lm:.3}"),
                    format!("{:.0}%", schedule.skip_fraction() * 100.0),
                ],
            ));
            eprintln!("[table1] ddim-{steps} {name}: done");
        }
        // paper sorts by TMACs descending within a step group
        rows.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (_, row) in rows {
            table.row(&row);
        }
    }

    println!("\nTable 1 — DiT image family, DDIM (paper: DiT-XL-256x256; ours: blob-DiT proxy)");
    table.print();
    std::fs::write("bench_out/table1_image.csv", table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
