//! §2.2 / §4 ablation: grouped-by-type caching decisions (the paper's
//! choice, mitigating cascading approximation error) vs independent
//! per-(block, branch) decisions at the same alpha. The paper argues
//! grouping is needed because per-site calibration errors stop
//! predicting true errors once earlier layers are approximated.
//!
//! Flags: `--threads N`, `--smoke` (CI scale), `--json OUT`
//! (machine-readable report, docs/benchmarks.md).

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef};
use smoothcache::experiments::{eval_conds, generate_set, image_corpus, EvalConfig};
use smoothcache::model::Engine;
use smoothcache::quality::{ffd, lpips_proxy, FeatureExtractor};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    // `--threads N` pins the GEMM pool per evaluation (0 = auto)
    let threads = args.usize("threads", 0)?;
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();

    let (steps, n_samples, calib_samples) = if smoke {
        (6usize, 4usize, 1usize)
    } else if fast_mode() {
        (10, 12, 2)
    } else {
        (50, 24, 10)
    };

    let mut report = BenchReport::new("ablation_grouping");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", steps);
    report.meta("samples", n_samples);
    report.meta("threads", threads);
    report.meta("smoke", smoke);
    report.run_meta(0);

    let cc = CalibrationConfig {
        num_samples: calib_samples,
        ..CalibrationConfig::new(SolverKind::Ddim, steps)
    };
    let curves = calibrate(&engine, "image", &cc)?;
    eprintln!("[grouping] calibrated");

    let fx = FeatureExtractor::new(0xF1D, 12);
    let (corpus, _) = image_corpus(128, 0xC0FFEE);

    // paired no-cache reference for LPIPS
    let mut ec = EvalConfig::new("image", SolverKind::Ddim, steps).with_threads(threads);
    ec.n_samples = n_samples;
    let conds = eval_conds(&fm, n_samples, 777);
    let no_cache = CachePlan::no_cache(steps, &sites);
    let (ref_set, _) = generate_set(&engine, &ec, &conds, PlanRef::Plan(&no_cache))?;
    eprintln!("[grouping] reference set done");

    let mut table = Table::new(&[
        "alpha", "mode", "skip%", "FFD (dn)", "LPIPS vs no-cache (dn)", "lat(s)",
    ]);
    for alpha in [0.15, 0.3, 0.5] {
        let grouped =
            CachePlan::from_grouped(&curves.smoothcache_schedule(alpha, &bts), &sites)?;
        // the per-site map resolves through the same CachePlan surface —
        // site-set mismatches would fail loudly here, not mid-generation
        let per_site = CachePlan::from_site_map(
            &format!("per-site-a{alpha}"),
            steps,
            &sites,
            &curves.per_site_schedule(alpha),
        )?;
        for (mode_slug, mode_name, plan) in
            [("grouped", "grouped (paper)", &grouped), ("per_site", "per-site", &per_site)]
        {
            let skip = plan.skip_fraction();
            let (set, stats) = generate_set(&engine, &ec, &conds, PlanRef::Plan(plan))?;
            let ffd_v = ffd(&fx, &corpus, &set);
            let lpips_v = lpips_proxy(&fx, &ref_set, &set);
            if json_out.is_some() {
                // alpha values are fixed roster points, safe in the key
                let a = format!("a{}", (alpha * 100.0).round() as usize);
                report.metric_tol(&format!("{a}/{mode_slug}/skip_pct"), skip * 100.0, "%", true, 1.0)?;
                report.metric_tol(&format!("{a}/{mode_slug}/ffd"), ffd_v, "score", false, 2.0)?;
                report.metric_tol(&format!("{a}/{mode_slug}/lpips"), lpips_v, "score", false, 5.0)?;
                report.metric_tol(
                    &format!("{a}/{mode_slug}/latency_s"),
                    stats.per_sample_seconds,
                    "s",
                    false,
                    100.0,
                )?;
            }
            table.row(&[
                format!("{alpha}"),
                mode_name.into(),
                format!("{:.0}%", skip * 100.0),
                format!("{ffd_v:.3}"),
                format!("{lpips_v:.4}"),
                format!("{:.3}", stats.per_sample_seconds),
            ]);
            eprintln!("[grouping] alpha={alpha} {mode_name}: done");
        }
    }

    println!("\n§2.2 ablation — grouped vs per-site caching decisions (image, DDIM-{steps})");
    table.print();
    println!("paper expectation: per-site skips more at equal alpha but degrades quality\nmore per unit of compute saved (cascading approximation error).");
    std::fs::write("bench_out/ablation_grouping.csv", table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
