//! §3.3 + Fig. 9 / supplementary §6 ablation: calibration-set size.
//! The paper observes that 10 samples reliably regenerate the same
//! caching schedule and that more samples only shrink the CI, not move
//! the mean. We sweep N ∈ {1, 2, 5, 10, 20} and report (a) schedule
//! agreement with the N=10 reference at several alphas, (b) mean CI
//! width at k=1.
//!
//! Flags: `--smoke` (CI scale) and `--json OUT` (machine-readable
//! report, docs/benchmarks.md).

use smoothcache::cache::{calibrate, CalibrationConfig, Schedule};
use smoothcache::model::Engine;
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args, Table};

fn agreement(a: &Schedule, b: &Schedule) -> f64 {
    let mut same = 0usize;
    let mut total = 0usize;
    for (ra, rb) in a.decisions.iter().zip(&b.decisions) {
        for (da, db) in ra.iter().zip(rb) {
            total += 1;
            if da.is_compute() == db.is_compute() {
                same += 1;
            }
        }
    }
    same as f64 / total as f64
}

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let bts = fm.branch_types.clone();

    let (steps, sizes): (usize, Vec<usize>) = if smoke {
        (6, vec![1, 2])
    } else if fast_mode() {
        (10, vec![1, 2, 5])
    } else {
        (50, vec![1, 2, 5, 10, 20])
    };
    let alphas = [0.1, 0.2, 0.35, 0.5];

    let mut report = BenchReport::new("ablation_calibration");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", steps);
    report.meta("smoke", smoke);
    report.run_meta(0);

    // reference curves at the paper's N=10 (or max size in fast mode)
    let ref_n = *sizes.iter().rev().find(|&&n| n <= 10).unwrap();
    let mut curves_by_n = std::collections::BTreeMap::new();
    for &n in &sizes {
        let cc = CalibrationConfig {
            num_samples: n,
            seed: 0xCA11B,
            ..CalibrationConfig::new(SolverKind::Ddim, steps)
        };
        let t0 = std::time::Instant::now();
        let curves = calibrate(&engine, "image", &cc)?;
        eprintln!("[calib-ablation] N={n}: {:.1}s", t0.elapsed().as_secs_f64());
        curves_by_n.insert(n, curves);
    }

    let mut table = Table::new(&[
        "N samples", "agreement vs ref (mean over alphas)", "mean CI width (attn)",
        "mean CI width (ffn)",
    ]);
    let reference = &curves_by_n[&ref_n];
    for (&n, curves) in &curves_by_n {
        let mut agreements = Vec::new();
        for &alpha in &alphas {
            let s_ref = reference.smoothcache_schedule(alpha, &bts);
            let s_n = curves.smoothcache_schedule(alpha, &bts);
            agreements.push(agreement(&s_ref, &s_n));
        }
        let mean_agree = agreements.iter().sum::<f64>() / agreements.len() as f64;
        if json_out.is_some() {
            // deterministic given the pinned calibration seed
            report.metric_tol(&format!("n{n}/agreement_pct"), mean_agree * 100.0, "%", true, 2.0)?;
            report.metric_tol(
                &format!("n{n}/ci_width_attn"),
                curves.mean_ci_width("attn"),
                "L1",
                false,
                10.0,
            )?;
            report.metric_tol(
                &format!("n{n}/ci_width_ffn"),
                curves.mean_ci_width("ffn"),
                "L1",
                false,
                10.0,
            )?;
        }
        table.row(&[
            n.to_string(),
            format!("{:.1}%", mean_agree * 100.0),
            format!("{:.5}", curves.mean_ci_width("attn")),
            format!("{:.5}", curves.mean_ci_width("ffn")),
        ]);
    }

    println!(
        "\nFig. 9 / §3.3 ablation — calibration sample size (image, DDIM-{steps}; ref N={ref_n})"
    );
    table.print();
    println!(
        "paper claim: schedules are stable by N=10; CI narrows with N but the mean doesn't move"
    );
    std::fs::write("bench_out/ablation_calibration.csv", table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
