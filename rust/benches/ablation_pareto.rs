//! §3.3 ablation: the caching/sample-step Pareto front. Sweeps FORA n
//! and SmoothCache alpha across DDIM step counts on the image family and
//! prints the (GMACs, FFD) frontier — the paper's claim is that
//! SmoothCache's front dominates static caching's.
//!
//! Flags: `--threads N`, `--smoke` (CI scale), `--json OUT`
//! (machine-readable report, docs/benchmarks.md).

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef, Schedule};
use smoothcache::experiments::{eval_conds, generate_set, image_corpus, EvalConfig};
use smoothcache::macs::{as_gmacs, generation_macs};
use smoothcache::model::Engine;
use smoothcache::quality::{ffd, FeatureExtractor};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{ascii_plot, fast_mode, Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    // `--threads N` pins the GEMM pool per evaluation (0 = auto)
    let threads = args.usize("threads", 0)?;
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();

    let (steps_list, n_samples, calib_samples) = if smoke {
        (vec![6usize], 4usize, 1usize)
    } else if fast_mode() {
        (vec![10], 12, 2)
    } else {
        (vec![50], 24, 10)
    };
    let fx = FeatureExtractor::new(0xF1D, 12);
    let (corpus, _) = image_corpus(128, 0xC0FFEE);

    let mut report = BenchReport::new("ablation_pareto");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", steps_list[0]);
    report.meta("samples", n_samples);
    report.meta("threads", threads);
    report.meta("smoke", smoke);
    report.run_meta(0);

    let mut table = Table::new(&["steps", "method", "param", "skip%", "GMACs", "FFD", "lat(s)"]);
    let mut fora_pts: Vec<(f64, f64)> = Vec::new();
    let mut ours_pts: Vec<(f64, f64)> = Vec::new();

    for &steps in &steps_list {
        let cc = CalibrationConfig {
            num_samples: calib_samples,
            ..CalibrationConfig::new(SolverKind::Ddim, steps)
        };
        let curves = calibrate(&engine, "image", &cc)?;
        eprintln!("[pareto] calibrated ddim-{steps}");

        // slug: stable metric key (FORA by interval, ours by target
        // skip percent — not the calibrated alpha)
        let mut roster: Vec<(String, String, String, Schedule)> = Vec::new();
        let fora_ns: &[usize] = if smoke { &[2, 3] } else { &[2, 3, 4] };
        for &n in fora_ns {
            roster.push((
                format!("fora_n{n}"),
                "FORA".into(),
                format!("n={n}"),
                Schedule::fora(steps, &bts, n),
            ));
        }
        let targets: &[f64] = if smoke {
            &[0.35, 0.5]
        } else {
            &[0.2, 0.35, 0.5, 0.6, 2.0 / 3.0, 0.72]
        };
        for &target in targets {
            let (alpha, s) = curves.alpha_for_skip_fraction(target, &bts);
            roster.push((
                format!("ours_s{}", (target * 100.0).round() as usize),
                "Ours".into(),
                format!("a={alpha:.3}"),
                s,
            ));
        }

        // warmup
        {
            let mut ec = EvalConfig::new("image", SolverKind::Ddim, 2).with_threads(threads);
            ec.n_samples = 4;
            ec.cfg_scale = 1.5;
            let conds = eval_conds(&fm, 4, 1);
            let warm_plan = CachePlan::no_cache(2, &sites);
            let _ = generate_set(&engine, &ec, &conds, PlanRef::Plan(&warm_plan))?;
        }

        let emit_metrics = steps == steps_list[0] && json_out.is_some();
        for (slug, method, param, schedule) in &roster {
            let mut ec = EvalConfig::new("image", SolverKind::Ddim, steps).with_threads(threads);
            ec.n_samples = n_samples;
            ec.cfg_scale = 1.5; // paper protocol
            let conds = eval_conds(&fm, n_samples, 777);
            let plan = CachePlan::from_grouped(schedule, &sites)?;
            let (set, stats) = generate_set(&engine, &ec, &conds, PlanRef::Plan(&plan))?;
            let f = ffd(&fx, &corpus, &set);
            let g = as_gmacs(generation_macs(&fm, schedule, true));
            if emit_metrics {
                report.metric_tol(&format!("{slug}/ffd"), f, "score", false, 2.0)?;
                report.metric_tol(&format!("{slug}/gmacs"), g, "GMACs", false, 0.1)?;
                report.metric_tol(
                    &format!("{slug}/latency_s"),
                    stats.per_sample_seconds,
                    "s",
                    false,
                    100.0,
                )?;
            }
            table.row(&[
                steps.to_string(),
                method.clone(),
                param.clone(),
                format!("{:.0}%", schedule.skip_fraction() * 100.0),
                format!("{g:.2}"),
                format!("{f:.3}"),
                format!("{:.3}", stats.per_sample_seconds),
            ]);
            if method == "FORA" {
                fora_pts.push((g, f));
            } else {
                ours_pts.push((g, f));
            }
            eprintln!("[pareto] ddim-{steps} {method} {param}: done");
        }
    }

    println!("\n§3.3 ablation — caching/sample-step Pareto front (image, DDIM)");
    table.print();
    std::fs::write("bench_out/ablation_pareto.csv", table.to_csv())?;

    // crude frontier visual: FFD (y) over GMACs-sorted points (x)
    fora_pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    ours_pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let plot = ascii_plot(
        "Pareto: FFD (lower better) across increasing GMACs",
        &[
            ("FORA".into(), fora_pts.iter().map(|p| p.1).collect()),
            ("Ours".into(), ours_pts.iter().map(|p| p.1).collect()),
        ],
        10,
    );
    println!("{plot}");
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
