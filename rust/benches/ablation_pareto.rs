//! §3.3 ablation: the caching/sample-step Pareto front. Sweeps FORA n
//! and SmoothCache alpha across DDIM step counts on the image family and
//! prints the (GMACs, FFD) frontier — the paper's claim is that
//! SmoothCache's front dominates static caching's.

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef, Schedule};
use smoothcache::experiments::{eval_conds, generate_set, image_corpus, EvalConfig};
use smoothcache::macs::{as_gmacs, generation_macs};
use smoothcache::model::Engine;
use smoothcache::quality::{ffd, FeatureExtractor};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::{arg_usize, ascii_plot, fast_mode, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    // `--threads N` pins the GEMM pool per evaluation (0 = auto)
    let threads = arg_usize("threads", 0);
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();

    let (steps_list, n_samples, calib_samples) =
        if fast_mode() { (vec![10], 12, 2) } else { (vec![50], 24, 10) };
    let fx = FeatureExtractor::new(0xF1D, 12);
    let (corpus, _) = image_corpus(128, 0xC0FFEE);

    let mut table = Table::new(&["steps", "method", "param", "skip%", "GMACs", "FFD", "lat(s)"]);
    let mut fora_pts: Vec<(f64, f64)> = Vec::new();
    let mut ours_pts: Vec<(f64, f64)> = Vec::new();

    for &steps in &steps_list {
        let cc = CalibrationConfig {
            num_samples: calib_samples,
            ..CalibrationConfig::new(SolverKind::Ddim, steps)
        };
        let curves = calibrate(&engine, "image", &cc)?;
        eprintln!("[pareto] calibrated ddim-{steps}");

        let mut roster: Vec<(String, String, Schedule)> = Vec::new();
        for n in [2usize, 3, 4] {
            roster.push(("FORA".into(), format!("n={n}"), Schedule::fora(steps, &bts, n)));
        }
        for target in [0.2, 0.35, 0.5, 0.6, 2.0 / 3.0, 0.72] {
            let (alpha, s) = curves.alpha_for_skip_fraction(target, &bts);
            roster.push(("Ours".into(), format!("a={alpha:.3}"), s));
        }

        // warmup
        {
            let mut ec = EvalConfig::new("image", SolverKind::Ddim, 2).with_threads(threads);
            ec.n_samples = 4;
            ec.cfg_scale = 1.5;
            let conds = eval_conds(&fm, 4, 1);
            let warm_plan = CachePlan::no_cache(2, &sites);
            let _ = generate_set(&engine, &ec, &conds, PlanRef::Plan(&warm_plan))?;
        }

        for (method, param, schedule) in &roster {
            let mut ec = EvalConfig::new("image", SolverKind::Ddim, steps).with_threads(threads);
            ec.n_samples = n_samples;
            ec.cfg_scale = 1.5; // paper protocol
            let conds = eval_conds(&fm, n_samples, 777);
            let plan = CachePlan::from_grouped(schedule, &sites)?;
            let (set, stats) = generate_set(&engine, &ec, &conds, PlanRef::Plan(&plan))?;
            let f = ffd(&fx, &corpus, &set);
            let g = as_gmacs(generation_macs(&fm, schedule, true));
            table.row(&[
                steps.to_string(),
                method.clone(),
                param.clone(),
                format!("{:.0}%", schedule.skip_fraction() * 100.0),
                format!("{g:.2}"),
                format!("{f:.3}"),
                format!("{:.3}", stats.per_sample_seconds),
            ]);
            if method == "FORA" {
                fora_pts.push((g, f));
            } else {
                ours_pts.push((g, f));
            }
            eprintln!("[pareto] ddim-{steps} {method} {param}: done");
        }
    }

    println!("\n§3.3 ablation — caching/sample-step Pareto front (image, DDIM)");
    table.print();
    std::fs::write("bench_out/ablation_pareto.csv", table.to_csv())?;

    // crude frontier visual: FFD (y) over GMACs-sorted points (x)
    fora_pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    ours_pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let plot = ascii_plot(
        "Pareto: FFD (lower better) across increasing GMACs",
        &[
            ("FORA".into(), fora_pts.iter().map(|p| p.1).collect()),
            ("Ours".into(), ours_pts.iter().map(|p| p.1).collect()),
        ],
        10,
    );
    println!("{plot}");
    Ok(())
}
