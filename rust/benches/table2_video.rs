//! Table 2 reproduction: video family (OpenSora STDiT proxy) under
//! Rectified Flow at 30 steps with CFG 7.0. Rows: No-Cache plus two
//! SmoothCache points matching the paper's MAC reductions (~14% and
//! ~18%). LPIPS / PSNR / SSIM are computed against the no-cache
//! generations (the paper's protocol); VBench is the composite proxy
//! from DESIGN.md section 3.
//!
//! Flags: `--threads N`, `--smoke` (CI scale), `--json OUT`
//! (machine-readable report, docs/benchmarks.md).

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef};
use smoothcache::experiments::{
    eval_conds, fmt_pm, generate_set, mean_std, vbench_proxy, EvalConfig,
};
use smoothcache::macs::{as_gmacs, generation_macs};
use smoothcache::model::Engine;
use smoothcache::quality::{lpips_proxy, psnr, ssim, FeatureExtractor};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    // `--threads N` pins the GEMM pool per evaluation (0 = auto)
    let threads = args.usize("threads", 0)?;
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("video")?;
    let fm = engine.family_manifest("video")?.clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();

    let (steps, n_samples, trials, calib_samples) = if smoke {
        (4usize, 4usize, 1usize, 1usize)
    } else if fast_mode() {
        (8, 8, 1, 2)
    } else {
        (30, 16, 1, 10)
    };
    let solver = SolverKind::RectifiedFlow;
    let cfg_scale = 7.0f32;

    let mut report = BenchReport::new("table2_video");
    report.meta("family", "video");
    report.meta("solver", "rectified-flow");
    report.meta("steps", steps);
    report.meta("samples", n_samples);
    report.meta("trials", trials);
    report.meta("threads", threads);
    report.meta("smoke", smoke);
    report.run_meta(0);

    eprintln!("[table2] calibrating rf-{steps} (conditional, cfg=7) ...");
    let cc = CalibrationConfig {
        k_max: 5,
        cfg_scale,
        num_samples: calib_samples,
        ..CalibrationConfig::new(solver, steps)
    };
    let curves = calibrate(&engine, "video", &cc)?;

    // two alpha points matched to the paper's MAC reductions (Table 2:
    // 1612→1388 ≈ 14% and 1612→1321 ≈ 18%)
    let (a1, s1) = curves.alpha_for_skip_fraction(0.15, &bts);
    let (a2, s2) = curves.alpha_for_skip_fraction(0.22, &bts);

    let fx = FeatureExtractor::new(0x71D0, 12);
    let mut table = Table::new(&[
        "Schedule", "VBench-proxy (up)", "LPIPS (dn)", "PSNR (up)", "SSIM (up)", "GMACs",
        "Latency (s)", "skip%",
    ]);

    // reference (no-cache) sets per trial; the slug is the stable
    // metric key (keyed by target skip fraction, not calibrated alpha)
    let mut rows: Vec<Vec<String>> = Vec::new();
    let roster = [
        ("no_cache", "No Cache".to_string(), None),
        ("ours_s15", format!("Ours (a={a1:.3})"), Some(&s1)),
        ("ours_s22", format!("Ours (a={a2:.3})"), Some(&s2)),
    ];

    // warmup compile (batch 4 + cfg doubling → batch 8 executables)
    {
        let mut ec = EvalConfig::new("video", solver, 2).with_threads(threads);
        ec.n_samples = 4;
        ec.cfg_scale = cfg_scale;
        let conds = eval_conds(&fm, 4, 1);
        let warm_plan = CachePlan::no_cache(2, &sites);
        let _ = generate_set(&engine, &ec, &conds, PlanRef::Plan(&warm_plan))?;
    }

    // per-trial reference sets (paired with identical seeds/conds)
    let mut refs = Vec::new();
    for trial in 0..trials {
        let mut ec = EvalConfig::new("video", solver, steps).with_threads(threads);
        ec.n_samples = n_samples;
        ec.cfg_scale = cfg_scale;
        ec.base_seed = 4000 + trial as u64 * 500;
        let conds = eval_conds(&fm, n_samples, 555 + trial as u64);
        let no_cache = CachePlan::no_cache(steps, &sites);
        let (set, stats) = generate_set(&engine, &ec, &conds, PlanRef::Plan(&no_cache))?;
        refs.push((ec, conds, set, stats));
    }

    for (slug, name, sched) in &roster {
        if let Some(s) = sched {
            s.validate().unwrap();
        }
        let schedule_or_nocache = match sched {
            Some(s) => (*s).clone(),
            None => smoothcache::cache::Schedule::no_cache(steps, &bts),
        };
        let gmacs = as_gmacs(generation_macs(&fm, &schedule_or_nocache, true));
        let (mut vb, mut lp, mut ps, mut ss_, mut lat) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for (ec, conds, ref_set, ref_stats) in &refs {
            let (set, stats) = match sched {
                None => (ref_set.clone(), ref_stats.clone()),
                Some(s) => {
                    let plan = CachePlan::from_grouped(s, &sites)?;
                    generate_set(&engine, ec, conds, PlanRef::Plan(&plan))?
                }
            };
            vb.push(vbench_proxy(&fx, ref_set, &set));
            if sched.is_some() {
                lp.push(lpips_proxy(&fx, ref_set, &set));
                ps.push(psnr(ref_set, &set));
                ss_.push(ssim(ref_set, &set));
            }
            lat.push(stats.per_sample_seconds);
        }
        let (vm, vs) = mean_std(&vb);
        let (lm, _) = mean_std(&lat);
        if json_out.is_some() {
            report.metric_tol(&format!("{slug}/vbench"), vm, "score", true, 2.0)?;
            report.metric_tol(&format!("{slug}/gmacs"), gmacs, "GMACs", false, 0.1)?;
            report.metric_tol(&format!("{slug}/latency_s"), lm, "s", false, 100.0)?;
            report.metric_tol(
                &format!("{slug}/skip_pct"),
                schedule_or_nocache.skip_fraction() * 100.0,
                "%",
                true,
                1.0,
            )?;
            if !lp.is_empty() {
                report.metric_tol(&format!("{slug}/lpips"), mean_std(&lp).0, "score", false, 5.0)?;
                let p = mean_std(&ps).0;
                // psnr is +inf for bitwise-identical sets
                if p.is_finite() {
                    report.metric_tol(&format!("{slug}/psnr"), p, "dB", true, 5.0)?;
                }
                report.metric_tol(&format!("{slug}/ssim"), mean_std(&ss_).0, "score", true, 2.0)?;
            }
        }
        let lpips_cell = if lp.is_empty() {
            "-".to_string()
        } else {
            let (m, s) = mean_std(&lp);
            fmt_pm(m, s, 4)
        };
        let psnr_cell = if ps.is_empty() {
            "-".to_string()
        } else {
            let (m, s) = mean_std(&ps);
            fmt_pm(m, s, 2)
        };
        let ssim_cell = if ss_.is_empty() {
            "-".to_string()
        } else {
            let (m, s) = mean_std(&ss_);
            fmt_pm(m, s, 4)
        };
        rows.push(vec![
            name.clone(),
            fmt_pm(vm, vs, 2),
            lpips_cell,
            psnr_cell,
            ssim_cell,
            format!("{gmacs:.2}"),
            format!("{lm:.3}"),
            format!("{:.0}%", schedule_or_nocache.skip_fraction() * 100.0),
        ]);
        eprintln!("[table2] {name}: done");
    }

    for r in rows {
        table.row(&r);
    }
    println!("\nTable 2 — video family, Rectified Flow {steps} steps, CFG 7.0 (paper: OpenSora v1.2)");
    table.print();
    std::fs::write("bench_out/table2_video.csv", table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
